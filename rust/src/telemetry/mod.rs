//! Telemetry plane: a bounded, lock-free serving event bus with a
//! subscriber API and in-process aggregation (observability for the
//! serving runtime).
//!
//! The serving stack historically reported only post-mortem: a
//! [`crate::serve::ServeReport`] after the load finished. This module adds
//! the *during*: the Coordinator emits compact, fixed-size
//! [`TelemetryEvent`]s — admissions, drops (with reason), per-processor
//! task dispatch/completion, recovery activity (retry/remap/shed), served
//! records, and periodic heartbeats carrying per-processor utilization,
//! ready-queue depths, and in-flight counts — into a bounded
//! single-producer ring ([`TelemetryBus`]). A subscriber
//! ([`TelemetryBus::subscribe`] → [`TelemetryRx`]) drains the ring without
//! ever blocking the producer: when the ring is full the event is counted
//! and dropped ([`TelemetryRx::dropped`]), never waited on — a slow
//! subscriber cannot stall dispatch.
//!
//! ## The fifth determinism contract: no-subscriber invisibility
//!
//! With no subscriber attached the bus is **disarmed**: every emission
//! site costs one relaxed atomic load and a branch — no event is built, no
//! slot is written, no allocation happens (counting-allocator tested), and
//! the serving schedule is bit-identical to the subscriber-less runtime
//! (bench-guarded within 1.05× of the plain load test). Events are stamped
//! with the active [`crate::serve::Clock`], so virtual-clock replays of the
//! same seed emit bit-identical streams — fresh deployment or warm
//! ([`crate::serve::WarmDeployment`]) — including every retry and remap
//! under a chaos plan.
//!
//! [`MetricsAggregator`] folds a drained stream back into totals that
//! exactly reproduce the final report
//! ([`MetricsAggregator::consistent_with`], tested per arrival pattern).
#![warn(missing_docs)]

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::coordinator::DropReason;
use crate::Processor;

/// Default event-ring capacity (events). Allocated once at deployment
/// time, never on the dispatch path.
pub const DEFAULT_RING_CAPACITY: usize = 16_384;

/// Default heartbeat period, clock seconds (virtual seconds under the
/// virtual clock, wall seconds otherwise).
pub const DEFAULT_HEARTBEAT_PERIOD: f64 = 0.01;

/// One serving-runtime event. Every variant is `Copy` and heap-free, so
/// publishing an event writes a fixed-size slot and nothing else.
/// Timestamps come from the coordinator's active [`crate::serve::Clock`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TelemetryEvent {
    /// A group request passed admission.
    Admitted {
        /// Arrival timestamp, clock seconds.
        time: f64,
        /// Model group of the request.
        group: usize,
        /// Request sequence number.
        request: u64,
    },
    /// A group request was rejected at admission ([`DropReason::Overload`])
    /// or abandoned by recovery ([`DropReason::FaultShed`]).
    Dropped {
        /// Drop timestamp, clock seconds (the arrival time for admission
        /// rejections, the shed decision time for recovery sheds).
        time: f64,
        /// Model group of the request.
        group: usize,
        /// Request sequence number.
        request: u64,
        /// Why the request was dropped.
        reason: DropReason,
    },
    /// A subgraph task was handed to a worker.
    TaskDispatch {
        /// Dispatch timestamp, clock seconds.
        time: f64,
        /// Model group of the owning request.
        group: usize,
        /// Request sequence number.
        request: u64,
        /// Network index within the deployment.
        network: usize,
        /// Subgraph index within the network's partition.
        subgraph: usize,
        /// Processor the task was dispatched to.
        processor: Processor,
    },
    /// A subgraph task completed on its worker (successfully, or — without
    /// recovery enabled — with an engine error logged into the record).
    TaskComplete {
        /// Completion timestamp, clock seconds.
        time: f64,
        /// Model group of the owning request.
        group: usize,
        /// Request sequence number.
        request: u64,
        /// Network index within the deployment.
        network: usize,
        /// Subgraph index within the network's partition.
        subgraph: usize,
        /// Processor that executed the task.
        processor: Processor,
        /// Engine-reported execution duration, seconds.
        elapsed: f64,
    },
    /// Recovery retried a failed task attempt in place.
    Retry {
        /// Decision timestamp, clock seconds.
        time: f64,
        /// Model group of the owning request.
        group: usize,
        /// Request sequence number.
        request: u64,
        /// Network index within the deployment.
        network: usize,
        /// Subgraph index within the network's partition.
        subgraph: usize,
        /// Failed attempts so far on this (task, processor).
        attempt: u32,
        /// Backoff delay before the re-dispatch, seconds.
        backoff: f64,
    },
    /// Recovery remapped a persistently failing task onto another
    /// processor.
    Remap {
        /// Decision timestamp, clock seconds.
        time: f64,
        /// Model group of the owning request.
        group: usize,
        /// Request sequence number.
        request: u64,
        /// Network index within the deployment.
        network: usize,
        /// Subgraph index within the network's partition.
        subgraph: usize,
        /// Processor the task kept failing on.
        from: Processor,
        /// Processor the task was remapped to.
        to: Processor,
    },
    /// A group request was served to completion (its last member network
    /// finished). Carries the same fault accounting the
    /// [`crate::coordinator::ServedRequest`] record folds, so an aggregated
    /// stream reproduces the report's totals exactly.
    Served {
        /// Completion timestamp, clock seconds.
        time: f64,
        /// Model group of the request.
        group: usize,
        /// Request sequence number.
        request: u64,
        /// Open-loop arrival timestamp, clock seconds.
        arrival: f64,
        /// Makespan (completion − arrival), seconds.
        makespan: f64,
        /// Relative SLO deadline, when the load declared one.
        deadline: Option<f64>,
        /// `makespan > deadline`.
        violated: bool,
        /// Failed attempts retried in place for this request.
        retries: u32,
        /// Subgraph tasks remapped to another processor for this request.
        remaps: u32,
        /// Processor-seconds lost to failed attempts and retry backoff.
        degraded: f64,
    },
    /// A served request missed its deadline (emitted immediately after the
    /// corresponding [`TelemetryEvent::Served`]).
    DeadlineViolation {
        /// Completion timestamp, clock seconds.
        time: f64,
        /// Model group of the request.
        group: usize,
        /// Request sequence number.
        request: u64,
        /// Makespan of the violating request, seconds.
        makespan: f64,
        /// The deadline it missed, seconds.
        deadline: f64,
    },
    /// Periodic runtime gauge snapshot, emitted every heartbeat period of
    /// clock time while a subscriber is attached. Under the virtual clock
    /// heartbeat times derive from the event schedule, so replays emit
    /// bit-identical heartbeats.
    Heartbeat {
        /// Heartbeat timestamp, clock seconds (a multiple of the period).
        time: f64,
        /// Per-processor utilization since the load started: completed
        /// busy seconds / elapsed clock seconds, indexed by
        /// [`Processor::index`].
        rho: [f64; 3],
        /// Ready-queue depth per processor (schedulable tasks waiting for
        /// an idle worker).
        queue: [u32; 3],
        /// Workers with a task in flight.
        busy: u32,
        /// Admitted, unfinished group requests.
        in_flight: u32,
    },
}

impl TelemetryEvent {
    /// Short machine-readable tag of the variant (the `"event"` field of
    /// the JSON encoding).
    pub fn kind(&self) -> &'static str {
        match self {
            TelemetryEvent::Admitted { .. } => "admitted",
            TelemetryEvent::Dropped { .. } => "dropped",
            TelemetryEvent::TaskDispatch { .. } => "task_dispatch",
            TelemetryEvent::TaskComplete { .. } => "task_complete",
            TelemetryEvent::Retry { .. } => "retry",
            TelemetryEvent::Remap { .. } => "remap",
            TelemetryEvent::Served { .. } => "served",
            TelemetryEvent::DeadlineViolation { .. } => "deadline_violation",
            TelemetryEvent::Heartbeat { .. } => "heartbeat",
        }
    }

    /// The event's clock timestamp, seconds.
    pub fn time(&self) -> f64 {
        match *self {
            TelemetryEvent::Admitted { time, .. }
            | TelemetryEvent::Dropped { time, .. }
            | TelemetryEvent::TaskDispatch { time, .. }
            | TelemetryEvent::TaskComplete { time, .. }
            | TelemetryEvent::Retry { time, .. }
            | TelemetryEvent::Remap { time, .. }
            | TelemetryEvent::Served { time, .. }
            | TelemetryEvent::DeadlineViolation { time, .. }
            | TelemetryEvent::Heartbeat { time, .. } => time,
        }
    }

    /// Encode the event as one JSON object (no trailing newline).
    /// Hand-rolled — serde is unavailable offline — with fixed field names;
    /// floats use Rust's shortest round-trip formatting, so equal streams
    /// encode to byte-identical lines.
    pub fn to_json_line(&self) -> String {
        fn opt(d: Option<f64>) -> String {
            d.map_or_else(|| "null".to_string(), |v| format!("{v}"))
        }
        match *self {
            TelemetryEvent::Admitted { time, group, request } => format!(
                "{{\"event\":\"admitted\",\"t\":{time},\"group\":{group},\"request\":{request}}}"
            ),
            TelemetryEvent::Dropped { time, group, request, reason } => {
                let reason = match reason {
                    DropReason::Overload => "overload",
                    DropReason::FaultShed => "fault_shed",
                };
                format!(
                    "{{\"event\":\"dropped\",\"t\":{time},\"group\":{group},\"request\":{request},\"reason\":\"{reason}\"}}"
                )
            }
            TelemetryEvent::TaskDispatch { time, group, request, network, subgraph, processor } => {
                format!(
                    "{{\"event\":\"task_dispatch\",\"t\":{time},\"group\":{group},\"request\":{request},\"network\":{network},\"subgraph\":{subgraph},\"processor\":\"{}\"}}",
                    processor.name()
                )
            }
            TelemetryEvent::TaskComplete {
                time,
                group,
                request,
                network,
                subgraph,
                processor,
                elapsed,
            } => format!(
                "{{\"event\":\"task_complete\",\"t\":{time},\"group\":{group},\"request\":{request},\"network\":{network},\"subgraph\":{subgraph},\"processor\":\"{}\",\"elapsed\":{elapsed}}}",
                processor.name()
            ),
            TelemetryEvent::Retry { time, group, request, network, subgraph, attempt, backoff } => {
                format!(
                    "{{\"event\":\"retry\",\"t\":{time},\"group\":{group},\"request\":{request},\"network\":{network},\"subgraph\":{subgraph},\"attempt\":{attempt},\"backoff\":{backoff}}}"
                )
            }
            TelemetryEvent::Remap { time, group, request, network, subgraph, from, to } => format!(
                "{{\"event\":\"remap\",\"t\":{time},\"group\":{group},\"request\":{request},\"network\":{network},\"subgraph\":{subgraph},\"from\":\"{}\",\"to\":\"{}\"}}",
                from.name(),
                to.name()
            ),
            TelemetryEvent::Served {
                time,
                group,
                request,
                arrival,
                makespan,
                deadline,
                violated,
                retries,
                remaps,
                degraded,
            } => format!(
                "{{\"event\":\"served\",\"t\":{time},\"group\":{group},\"request\":{request},\"arrival\":{arrival},\"makespan\":{makespan},\"deadline\":{},\"violated\":{violated},\"retries\":{retries},\"remaps\":{remaps},\"degraded\":{degraded}}}",
                opt(deadline)
            ),
            TelemetryEvent::DeadlineViolation { time, group, request, makespan, deadline } => {
                format!(
                    "{{\"event\":\"deadline_violation\",\"t\":{time},\"group\":{group},\"request\":{request},\"makespan\":{makespan},\"deadline\":{deadline}}}"
                )
            }
            TelemetryEvent::Heartbeat { time, rho, queue, busy, in_flight } => format!(
                "{{\"event\":\"heartbeat\",\"t\":{time},\"rho\":[{},{},{}],\"queue\":[{},{},{}],\"busy\":{busy},\"in_flight\":{in_flight}}}",
                rho[0], rho[1], rho[2], queue[0], queue[1], queue[2]
            ),
        }
    }
}

// ---------------------------------------------------------------------------
// The ring

/// One pre-initialized ring slot. `Sync` is sound because slot access is
/// coordinated through the ring's head/tail counters: the producer writes a
/// slot only while it is invisible to the consumer (index ≥ head) and
/// published slots are read-only until the consumer retires them
/// (tail release / head acquire pairs order the accesses).
struct Slot(UnsafeCell<TelemetryEvent>);

// SAFETY: see the `Slot` doc comment — the head/tail protocol guarantees a
// slot is never written and read concurrently.
unsafe impl Sync for Slot {}

/// The shared ring state behind a [`TelemetryBus`] and its subscribers.
struct Ring {
    slots: Box<[Slot]>,
    /// Events ever published (producer-owned; consumer reads with acquire).
    head: AtomicU64,
    /// Events ever consumed (consumer-owned; producer reads with acquire).
    tail: AtomicU64,
    /// Events discarded because the ring was full (drop-on-full, counted).
    dropped: AtomicU64,
    /// Live subscriber count; 0 disarms every emission site.
    subscribers: AtomicU32,
    /// Serializes consumers (drains and cursor resets). Never touched by
    /// the producer.
    drain_lock: Mutex<()>,
}

impl Ring {
    fn with_capacity(capacity: usize) -> Ring {
        let filler = TelemetryEvent::Admitted { time: 0.0, group: 0, request: 0 };
        Ring {
            slots: (0..capacity.max(1)).map(|_| Slot(UnsafeCell::new(filler))).collect(),
            head: AtomicU64::new(0),
            tail: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            subscribers: AtomicU32::new(0),
            drain_lock: Mutex::new(()),
        }
    }

    /// Single-producer publish: write the next slot or count a drop when
    /// the ring is full. Never blocks, never allocates.
    fn publish(&self, ev: TelemetryEvent) {
        let head = self.head.load(Ordering::Relaxed); // producer-owned
        let tail = self.tail.load(Ordering::Acquire);
        if head - tail >= self.slots.len() as u64 {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let idx = (head % self.slots.len() as u64) as usize;
        // SAFETY: this slot is outside [tail, head), so no consumer reads
        // it; the release store below publishes the write.
        unsafe { *self.slots[idx].0.get() = ev };
        self.head.store(head + 1, Ordering::Release);
    }

    /// Consumer drain: move every published event into `out`. Returns the
    /// number drained. Serialized across consumers by `drain_lock`.
    fn drain_into(&self, out: &mut Vec<TelemetryEvent>) -> usize {
        let _guard = self.drain_lock.lock().expect("telemetry drain lock");
        let head = self.head.load(Ordering::Acquire);
        let mut tail = self.tail.load(Ordering::Relaxed);
        let n = (head - tail) as usize;
        out.reserve(n);
        while tail < head {
            let idx = (tail % self.slots.len() as u64) as usize;
            // SAFETY: slots in [tail, head) were published by the acquire
            // load above and are not rewritten until the tail store below
            // retires them.
            out.push(unsafe { *self.slots[idx].0.get() });
            tail += 1;
            // Retire the slot immediately so the producer regains capacity
            // as the drain progresses.
            self.tail.store(tail, Ordering::Release);
        }
        n
    }
}

/// Producer-side handle of the event ring, embedded in the Coordinator.
///
/// Emission ([`TelemetryBus::emit`]) is a single relaxed atomic load and a
/// branch while no subscriber is attached, and a bounded lock-free ring
/// write (drop-on-full, counted) while one is. The producer never blocks
/// and never allocates; all emission must happen from one thread at a time
/// (the coordinator-driving thread — guaranteed by the Coordinator's
/// `&mut` drivers).
pub struct TelemetryBus {
    ring: Arc<Ring>,
}

impl TelemetryBus {
    /// A bus with the default ring capacity
    /// ([`DEFAULT_RING_CAPACITY`] events).
    pub fn new() -> TelemetryBus {
        TelemetryBus::with_capacity(DEFAULT_RING_CAPACITY)
    }

    /// A bus whose ring holds `capacity` events (allocated now, never on
    /// the dispatch path).
    pub fn with_capacity(capacity: usize) -> TelemetryBus {
        TelemetryBus { ring: Arc::new(Ring::with_capacity(capacity)) }
    }

    /// True while at least one subscriber is attached. One relaxed atomic
    /// load — the entire cost of the telemetry plane when disarmed.
    #[inline]
    pub fn armed(&self) -> bool {
        self.ring.subscribers.load(Ordering::Relaxed) > 0
    }

    /// Publish an event if a subscriber is attached; otherwise do nothing.
    #[inline]
    pub fn emit(&self, ev: TelemetryEvent) {
        if self.armed() {
            self.ring.publish(ev);
        }
    }

    /// Attach a subscriber and arm the bus. The new subscription starts
    /// from *now*: events already in the ring are discarded and the
    /// drop-on-full counter restarts. Subscribers share one cursor (a
    /// drained event is delivered to exactly one of them), so a single
    /// subscriber per deployment is the intended shape.
    pub fn subscribe(&self) -> TelemetryRx {
        {
            let _guard = self.ring.drain_lock.lock().expect("telemetry drain lock");
            let head = self.ring.head.load(Ordering::Acquire);
            self.ring.tail.store(head, Ordering::Release);
            self.ring.dropped.store(0, Ordering::Relaxed);
        }
        self.ring.subscribers.fetch_add(1, Ordering::Relaxed);
        TelemetryRx { ring: self.ring.clone() }
    }

    /// Events discarded because the ring was full since the last
    /// [`TelemetryBus::subscribe`].
    pub fn dropped(&self) -> u64 {
        self.ring.dropped.load(Ordering::Relaxed)
    }
}

impl Default for TelemetryBus {
    fn default() -> Self {
        TelemetryBus::new()
    }
}

/// Subscriber handle: non-blocking drains of the event ring. Dropping the
/// handle detaches the subscription; when the last subscriber detaches the
/// bus disarms and emission returns to the one-atomic-load fast path.
pub struct TelemetryRx {
    ring: Arc<Ring>,
}

impl TelemetryRx {
    /// Drain every published event (non-blocking; empty when none are
    /// pending).
    pub fn drain(&mut self) -> Vec<TelemetryEvent> {
        let mut out = Vec::new();
        self.ring.drain_into(&mut out);
        out
    }

    /// Drain into an existing buffer (appends). Returns the number drained.
    pub fn drain_into(&mut self, out: &mut Vec<TelemetryEvent>) -> usize {
        self.ring.drain_into(out)
    }

    /// Events the producer discarded because the ring was full (slow
    /// subscriber) since this subscription was created.
    pub fn dropped(&self) -> u64 {
        self.ring.dropped.load(Ordering::Relaxed)
    }
}

impl Drop for TelemetryRx {
    fn drop(&mut self) {
        self.ring.subscribers.fetch_sub(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// Coordinator-side state (bus + heartbeat bookkeeping)

/// The Coordinator's telemetry state: the event bus plus the per-load
/// heartbeat bookkeeping (per-processor completed busy seconds and the
/// next heartbeat due time). Reset at the start of every load window so
/// warm replays emit the same heartbeats as fresh deployments.
pub struct Telemetry {
    bus: TelemetryBus,
    /// Completed busy seconds per processor since the load window started.
    busy: [f64; 3],
    /// Next heartbeat due time, clock seconds.
    next_heartbeat: f64,
    period: f64,
}

impl Telemetry {
    /// Telemetry state with a default-capacity bus and the default
    /// heartbeat period.
    pub fn new() -> Telemetry {
        Telemetry {
            bus: TelemetryBus::new(),
            busy: [0.0; 3],
            next_heartbeat: DEFAULT_HEARTBEAT_PERIOD,
            period: DEFAULT_HEARTBEAT_PERIOD,
        }
    }

    /// The underlying bus (emission and subscription).
    pub fn bus(&self) -> &TelemetryBus {
        &self.bus
    }

    /// True while a subscriber is attached (delegates to
    /// [`TelemetryBus::armed`]).
    #[inline]
    pub fn armed(&self) -> bool {
        self.bus.armed()
    }

    /// Publish an event if armed ([`TelemetryBus::emit`]).
    #[inline]
    pub fn emit(&self, ev: TelemetryEvent) {
        self.bus.emit(ev);
    }

    /// Attach a subscriber ([`TelemetryBus::subscribe`]).
    pub fn subscribe(&self) -> TelemetryRx {
        self.bus.subscribe()
    }

    /// Change the heartbeat period (clock seconds; clamped to ≥ 1 µs).
    /// Takes effect at the next load window.
    pub fn set_heartbeat_period(&mut self, period: f64) {
        self.period = period.max(1e-6);
    }

    /// Start a new load window: zero the busy accumulators and re-arm the
    /// heartbeat schedule at one period from the window's t = 0.
    pub fn begin_window(&mut self) {
        self.busy = [0.0; 3];
        self.next_heartbeat = self.period;
    }

    /// Account completed busy time on a processor (heartbeat ρ numerator).
    /// Gated on the armed flag so the disarmed path stays a load + branch.
    #[inline]
    pub fn on_busy(&mut self, p: Processor, seconds: f64) {
        if self.bus.armed() {
            self.busy[p.index()] += seconds;
        }
    }

    /// True when at least one heartbeat is due at clock time `now` (armed
    /// and past the schedule). The caller gathers the gauge snapshot and
    /// calls [`Telemetry::emit_heartbeats`] only when this returns true, so
    /// the disarmed cost stays one load + branch.
    #[inline]
    pub fn heartbeat_due(&self, now: f64) -> bool {
        self.bus.armed() && now >= self.next_heartbeat
    }

    /// Emit every heartbeat due at clock time `now`, carrying the given
    /// gauge snapshot (ready-queue depths, busy workers, in-flight group
    /// requests). Heartbeat times are schedule multiples — derived from the
    /// event times, not the OS — so virtual replays are bit-identical.
    pub fn emit_heartbeats(&mut self, now: f64, queue: [u32; 3], busy: u32, in_flight: u32) {
        while self.next_heartbeat <= now {
            let t = self.next_heartbeat;
            let mut rho = [0.0f64; 3];
            for (r, b) in rho.iter_mut().zip(self.busy.iter()) {
                *r = if t > 0.0 { b / t } else { 0.0 };
            }
            self.bus.emit(TelemetryEvent::Heartbeat { time: t, rho, queue, busy, in_flight });
            self.next_heartbeat += self.period;
        }
    }
}

impl Default for Telemetry {
    fn default() -> Self {
        Telemetry::new()
    }
}

// ---------------------------------------------------------------------------
// Aggregation

/// Folds a drained event stream into running totals that mirror the final
/// [`crate::serve::ServeReport`] of the same load — the in-process sink.
///
/// The consistency contract ([`MetricsAggregator::consistent_with`],
/// tested): after folding every event of one load window, `submitted`,
/// `served`, `dropped`, `violations`, `fault_shed`, `retries`, `remaps`,
/// `degraded_time`, and the derived attainment equal the report's fields
/// exactly (bit-equal floats — the fold order matches the report's
/// completion-order fold).
#[derive(Debug, Clone, Default)]
pub struct MetricsAggregator {
    /// Requests that passed admission.
    pub admitted: usize,
    /// Requests served to completion.
    pub served: usize,
    /// Requests dropped at admission (overload).
    pub overload_drops: usize,
    /// Requests shed by recovery.
    pub fault_shed: usize,
    /// Served requests that missed their deadline.
    pub violations: usize,
    /// Retries folded from served requests (matches the report, which
    /// counts only requests that eventually completed).
    pub retries: u64,
    /// Remaps folded from served requests.
    pub remaps: u64,
    /// Degraded processor-seconds folded from served requests.
    pub degraded_time: f64,
    /// Retry decisions observed live (includes requests later shed — a
    /// superset of `retries`).
    pub retry_events: u64,
    /// Remap decisions observed live (includes requests later shed).
    pub remap_events: u64,
    /// Tasks dispatched per processor.
    pub dispatches: [u64; 3],
    /// Tasks completed per processor.
    pub completions: [u64; 3],
    /// Completed busy seconds per processor.
    pub busy_seconds: [f64; 3],
    /// Heartbeats observed.
    pub heartbeats: usize,
    /// The most recent heartbeat, when any was observed.
    pub last_heartbeat: Option<TelemetryEvent>,
    /// Sum of served makespans, seconds.
    pub makespan_sum: f64,
    /// Largest served makespan, seconds.
    pub max_makespan: f64,
}

impl MetricsAggregator {
    /// An empty aggregator.
    pub fn new() -> MetricsAggregator {
        MetricsAggregator::default()
    }

    /// Fold one event into the totals.
    pub fn fold(&mut self, ev: &TelemetryEvent) {
        match *ev {
            TelemetryEvent::Admitted { .. } => self.admitted += 1,
            TelemetryEvent::Dropped { reason, .. } => match reason {
                DropReason::Overload => self.overload_drops += 1,
                DropReason::FaultShed => self.fault_shed += 1,
            },
            TelemetryEvent::TaskDispatch { processor, .. } => {
                self.dispatches[processor.index()] += 1;
            }
            TelemetryEvent::TaskComplete { processor, elapsed, .. } => {
                self.completions[processor.index()] += 1;
                self.busy_seconds[processor.index()] += elapsed.max(0.0);
            }
            TelemetryEvent::Retry { .. } => self.retry_events += 1,
            TelemetryEvent::Remap { .. } => self.remap_events += 1,
            TelemetryEvent::Served { makespan, violated, retries, remaps, degraded, .. } => {
                self.served += 1;
                if violated {
                    self.violations += 1;
                }
                self.retries += retries as u64;
                self.remaps += remaps as u64;
                self.degraded_time += degraded;
                self.makespan_sum += makespan;
                self.max_makespan = self.max_makespan.max(makespan);
            }
            TelemetryEvent::DeadlineViolation { .. } => {}
            TelemetryEvent::Heartbeat { .. } => {
                self.heartbeats += 1;
                self.last_heartbeat = Some(*ev);
            }
        }
    }

    /// Fold a whole drained stream.
    pub fn fold_all(&mut self, events: &[TelemetryEvent]) {
        for ev in events {
            self.fold(ev);
        }
    }

    /// Total requests submitted to admission (admitted + overload drops).
    pub fn submitted(&self) -> usize {
        self.admitted + self.overload_drops
    }

    /// Total requests dropped (overload + fault-shed) — the report's
    /// `dropped`.
    pub fn dropped(&self) -> usize {
        self.overload_drops + self.fault_shed
    }

    /// Check the folded totals against the final report of the same load.
    /// Returns every mismatching field, or `Ok` when the stream exactly
    /// reproduces the report (the consistency contract).
    pub fn consistent_with(&self, report: &crate::serve::ServeReport) -> Result<(), String> {
        let mut mismatches: Vec<String> = Vec::new();
        let mut check = |name: &str, stream: String, report: String| {
            if stream != report {
                mismatches.push(format!("{name}: stream {stream} vs report {report}"));
            }
        };
        check("submitted", self.submitted().to_string(), report.submitted.to_string());
        check("served", self.served.to_string(), report.served.to_string());
        check("dropped", self.dropped().to_string(), report.dropped.to_string());
        check("violations", self.violations.to_string(), report.violations.to_string());
        check("fault_shed", self.fault_shed.to_string(), report.fault_shed.to_string());
        check("retries", self.retries.to_string(), report.retries.to_string());
        check("remaps", self.remaps.to_string(), report.remaps.to_string());
        check(
            "degraded_time",
            self.degraded_time.to_bits().to_string(),
            report.degraded_time.to_bits().to_string(),
        );
        let met = self.served - self.violations;
        let attainment =
            if self.submitted() == 0 { 1.0 } else { met as f64 / self.submitted() as f64 };
        check(
            "attainment",
            attainment.to_bits().to_string(),
            report.attainment.to_bits().to_string(),
        );
        if mismatches.is_empty() {
            Ok(())
        } else {
            Err(mismatches.join("; "))
        }
    }

    /// One-line human summary (the TTY monitor's aggregate line).
    pub fn summary_line(&self) -> String {
        format!(
            "submitted {} served {} dropped {} (overload {}, shed {}) violations {} retries {} remaps {} heartbeats {}",
            self.submitted(),
            self.served,
            self.dropped(),
            self.overload_drops,
            self.fault_shed,
            self.violations,
            self.retry_events,
            self.remap_events,
            self.heartbeats,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(i: u64) -> TelemetryEvent {
        TelemetryEvent::Admitted { time: i as f64, group: 0, request: i }
    }

    #[test]
    fn disarmed_emission_is_free_and_invisible() {
        let bus = TelemetryBus::with_capacity(8);
        assert!(!bus.armed());
        let before = crate::util::alloc::thread_allocations();
        for i in 0..1000 {
            bus.emit(ev(i));
        }
        assert_eq!(
            crate::util::alloc::thread_allocations() - before,
            0,
            "disarmed emission must not allocate"
        );
        // Nothing was published: a new subscriber sees an empty ring.
        let mut rx = bus.subscribe();
        assert!(rx.drain().is_empty());
    }

    #[test]
    fn armed_publish_is_allocation_free_and_drops_on_full() {
        let bus = TelemetryBus::with_capacity(16);
        let mut rx = bus.subscribe();
        let before = crate::util::alloc::thread_allocations();
        for i in 0..64 {
            bus.emit(ev(i));
        }
        assert_eq!(
            crate::util::alloc::thread_allocations() - before,
            0,
            "armed publish must not allocate (pre-sized ring)"
        );
        assert_eq!(rx.dropped(), 48, "overflow must be counted, not blocked on");
        let got = rx.drain();
        assert_eq!(got.len(), 16);
        // The oldest 16: drop-on-full discards the *new* event.
        assert_eq!(got[0], ev(0));
        assert_eq!(got[15], ev(15));
    }

    #[test]
    fn drain_frees_capacity_and_preserves_order() {
        let bus = TelemetryBus::with_capacity(4);
        let mut rx = bus.subscribe();
        let mut seen = Vec::new();
        for round in 0..5u64 {
            for i in 0..4 {
                bus.emit(ev(round * 4 + i));
            }
            rx.drain_into(&mut seen);
        }
        assert_eq!(rx.dropped(), 0);
        assert_eq!(seen.len(), 20);
        for (i, e) in seen.iter().enumerate() {
            assert_eq!(*e, ev(i as u64), "order broken at {i}");
        }
    }

    #[test]
    fn subscriber_drop_disarms_and_resubscribe_starts_fresh() {
        let bus = TelemetryBus::with_capacity(8);
        let rx = bus.subscribe();
        assert!(bus.armed());
        bus.emit(ev(1));
        drop(rx);
        assert!(!bus.armed());
        bus.emit(ev(2)); // disarmed: discarded without counting
        let mut rx = bus.subscribe();
        assert_eq!(bus.dropped(), 0, "subscribe restarts the drop counter");
        assert!(rx.drain().is_empty(), "a new subscription starts from now");
        bus.emit(ev(3));
        assert_eq!(rx.drain(), vec![ev(3)]);
    }

    #[test]
    fn cross_thread_drain_sees_every_event() {
        let bus = TelemetryBus::with_capacity(1024);
        let mut rx = bus.subscribe();
        let consumer = std::thread::spawn(move || {
            let mut seen = Vec::new();
            while seen.len() < 10_000 {
                rx.drain_into(&mut seen);
            }
            (seen, rx)
        });
        for i in 0..10_000 {
            loop {
                // The producer never blocks in the runtime; here we retry
                // on full so the test asserts lossless transfer.
                let before = bus.dropped();
                bus.emit(ev(i));
                if bus.dropped() == before {
                    break;
                }
            }
        }
        let (seen, _rx) = consumer.join().expect("consumer thread");
        assert_eq!(seen.len(), 10_000);
        for (i, e) in seen.iter().enumerate() {
            assert_eq!(*e, ev(i as u64));
        }
    }

    #[test]
    fn heartbeat_schedule_and_rho_accounting() {
        let mut t = Telemetry::new();
        t.set_heartbeat_period(0.5);
        t.begin_window();
        let mut rx = t.subscribe();
        t.on_busy(Processor::Npu, 0.25);
        assert!(!t.heartbeat_due(0.4));
        assert!(t.heartbeat_due(1.1));
        t.emit_heartbeats(1.1, [1, 0, 2], 1, 3);
        let evs = rx.drain();
        assert_eq!(evs.len(), 2, "two periods elapsed: two heartbeats");
        match evs[0] {
            TelemetryEvent::Heartbeat { time, rho, queue, busy, in_flight } => {
                assert_eq!(time, 0.5);
                assert!((rho[Processor::Npu.index()] - 0.5).abs() < 1e-12);
                assert_eq!(queue, [1, 0, 2]);
                assert_eq!((busy, in_flight), (1, 3));
            }
            other => panic!("expected heartbeat, got {other:?}"),
        }
        assert_eq!(evs[1].time(), 1.0);
        // begin_window rewinds the schedule and the accumulators.
        t.begin_window();
        assert!(!t.heartbeat_due(0.4));
        t.emit_heartbeats(0.5, [0, 0, 0], 0, 0);
        match rx.drain()[0] {
            TelemetryEvent::Heartbeat { rho, .. } => {
                assert_eq!(rho, [0.0; 3], "busy accumulators must reset per window");
            }
            other => panic!("expected heartbeat, got {other:?}"),
        }
    }

    #[test]
    fn json_lines_are_well_formed_for_every_variant() {
        let variants = vec![
            ev(3),
            TelemetryEvent::Dropped {
                time: 0.5,
                group: 1,
                request: 2,
                reason: DropReason::FaultShed,
            },
            TelemetryEvent::TaskDispatch {
                time: 0.1,
                group: 0,
                request: 1,
                network: 2,
                subgraph: 3,
                processor: Processor::Gpu,
            },
            TelemetryEvent::TaskComplete {
                time: 0.2,
                group: 0,
                request: 1,
                network: 2,
                subgraph: 3,
                processor: Processor::Gpu,
                elapsed: 0.01,
            },
            TelemetryEvent::Retry {
                time: 0.3,
                group: 0,
                request: 1,
                network: 0,
                subgraph: 0,
                attempt: 2,
                backoff: 0.004,
            },
            TelemetryEvent::Remap {
                time: 0.4,
                group: 0,
                request: 1,
                network: 0,
                subgraph: 0,
                from: Processor::Npu,
                to: Processor::Gpu,
            },
            TelemetryEvent::Served {
                time: 0.6,
                group: 0,
                request: 1,
                arrival: 0.0,
                makespan: 0.6,
                deadline: Some(0.5),
                violated: true,
                retries: 1,
                remaps: 0,
                degraded: 0.02,
            },
            TelemetryEvent::Served {
                time: 0.6,
                group: 0,
                request: 1,
                arrival: 0.0,
                makespan: 0.6,
                deadline: None,
                violated: false,
                retries: 0,
                remaps: 0,
                degraded: 0.0,
            },
            TelemetryEvent::DeadlineViolation {
                time: 0.6,
                group: 0,
                request: 1,
                makespan: 0.6,
                deadline: 0.5,
            },
            TelemetryEvent::Heartbeat {
                time: 0.5,
                rho: [0.25, 0.0, 1.5],
                queue: [0, 1, 2],
                busy: 2,
                in_flight: 4,
            },
        ];
        for v in &variants {
            let line = v.to_json_line();
            assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
            assert!(line.contains(&format!("\"event\":\"{}\"", v.kind())), "{line}");
            assert!(!line.contains('\n'), "one line per event: {line}");
            // Balanced braces/brackets and no bare NaN/inf tokens.
            assert_eq!(line.matches('{').count(), line.matches('}').count(), "{line}");
            assert_eq!(line.matches('[').count(), line.matches(']').count(), "{line}");
            assert!(!line.contains("NaN") && !line.contains("inf"), "{line}");
        }
    }

    #[test]
    fn aggregator_folds_and_checks_consistency() {
        let mut agg = MetricsAggregator::new();
        agg.fold_all(&[
            ev(0),
            ev(1),
            TelemetryEvent::Dropped {
                time: 0.1,
                group: 0,
                request: 2,
                reason: DropReason::Overload,
            },
            TelemetryEvent::Served {
                time: 0.2,
                group: 0,
                request: 0,
                arrival: 0.0,
                makespan: 0.2,
                deadline: Some(0.5),
                violated: false,
                retries: 1,
                remaps: 0,
                degraded: 0.05,
            },
            TelemetryEvent::Served {
                time: 0.9,
                group: 0,
                request: 1,
                arrival: 0.1,
                makespan: 0.8,
                deadline: Some(0.5),
                violated: true,
                retries: 0,
                remaps: 1,
                degraded: 0.01,
            },
            TelemetryEvent::DeadlineViolation {
                time: 0.9,
                group: 0,
                request: 1,
                makespan: 0.8,
                deadline: 0.5,
            },
        ]);
        assert_eq!(agg.submitted(), 3);
        assert_eq!((agg.served, agg.dropped(), agg.violations), (2, 1, 1));
        assert_eq!((agg.retries, agg.remaps), (1, 1));
        assert!((agg.degraded_time - 0.06).abs() < 1e-12);
        assert!((agg.max_makespan - 0.8).abs() < 1e-12);

        // Against a matching hand-built report fold.
        let served = vec![
            crate::coordinator::ServedRequest {
                group: 0,
                request: 0,
                arrival: 0.0,
                completion: 0.2,
                makespan: 0.2,
                deadline: Some(0.5),
                violated: false,
                retries: 1,
                remaps: 0,
                degraded: 0.05,
            },
            crate::coordinator::ServedRequest {
                group: 0,
                request: 1,
                arrival: 0.1,
                completion: 0.9,
                makespan: 0.8,
                deadline: Some(0.5),
                violated: true,
                retries: 0,
                remaps: 1,
                degraded: 0.01,
            },
        ];
        let report =
            crate::serve::ServeReport::from_log(&served, 1, 3, &[Some(0.5)], 1.0, 0.0);
        agg.consistent_with(&report).expect("stream must reproduce the report");
        // And a deliberate mismatch is caught.
        let mut wrong = agg.clone();
        wrong.admitted += 1;
        let err = wrong.consistent_with(&report).unwrap_err();
        assert!(err.contains("submitted"), "{err}");
    }
}
