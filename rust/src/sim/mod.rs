//! Discrete-event simulator of the Puzzle Runtime (paper §4.3).
//!
//! The paper uses a "simple simulator" (SimPy) that replicates runtime
//! behaviour — per-processor serial workers, subgraph dependencies,
//! communication costs, network priorities, periodic request arrivals — to
//! evaluate GA candidates cheaply during local search. This module rebuilds
//! that substrate as a fast event-driven simulator in rust: it is the GA's
//! inner-loop hot path (evaluated tens of thousands of times per search), so
//! it works on flat index-based structures with a binary-heap event queue.
//!
//! Inputs are [`ExecutionPlan`]s (one per network: subgraph durations from
//! the device-in-the-loop profiler, processor mapping, transfer byte counts)
//! plus [`GroupSpec`]s (model groups with periods). Output is the per-group
//! makespan series the XRBench metrics consume.
//!
//! The hot path is split into two pieces (§Perf, this PR):
//! * [`CompiledPlan`] — flat CSR dependency metadata built **once per
//!   decode** (the seed rebuilt it inside every `simulate()` call);
//! * [`SimWorkspace`] — a reusable arena owning the event heap, instance
//!   table, ready queues, and scratch buffers, so steady-state evaluation
//!   performs zero heap allocation.
//!
//! [`simulate`] remains the convenience entry point (compile + fresh
//! workspace + owned [`SimResult`]); batch evaluation in
//! [`crate::analyzer`] drives [`SimWorkspace::run`] directly.

mod compiled;
mod workspace;

pub use compiled::{compile_plans, CompiledPlan};
pub use workspace::SimWorkspace;

use crate::comm::CommModel;
use crate::Processor;

/// One subgraph execution template within a network's plan.
#[derive(Debug, Clone, PartialEq)]
pub struct PlannedTask {
    /// Profiled (measured) execution duration, seconds.
    pub duration: f64,
    /// Worker that runs this subgraph.
    pub processor: Processor,
}

/// A tensor transfer between two subgraphs of the same network.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlannedTransfer {
    pub from: usize,
    pub to: usize,
    pub bytes: usize,
}

/// The executable plan for one network: its partitioned subgraphs, their
/// dependencies, and its scheduling priority (lower value = dispatched
/// first when competing for a worker).
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutionPlan {
    pub tasks: Vec<PlannedTask>,
    pub transfers: Vec<PlannedTransfer>,
    pub priority: usize,
}

impl ExecutionPlan {
    /// Critical-path lower bound on one isolated request's latency
    /// (ignoring worker contention; used for sanity checks and seeds).
    pub fn critical_path(&self, comm: &CommModel, zero_copy: bool) -> f64 {
        let n = self.tasks.len();
        // Kahn order over the transfer DAG (subgraph ids are not guaranteed
        // to be topologically numbered).
        let mut indeg = vec![0usize; n];
        for tr in &self.transfers {
            indeg[tr.to] += 1;
        }
        let mut order: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut head = 0;
        let mut dist = vec![0.0f64; n];
        while head < order.len() {
            let i = order[head];
            head += 1;
            dist[i] += self.tasks[i].duration;
            for tr in self.transfers.iter().filter(|t| t.from == i) {
                let same = self.tasks[tr.from].processor == self.tasks[tr.to].processor;
                let c = if zero_copy {
                    comm.transfer_cost_zero_copy(tr.bytes, same)
                } else {
                    comm.transfer_cost(tr.bytes, same)
                };
                dist[tr.to] = dist[tr.to].max(dist[i] + c);
                indeg[tr.to] -= 1;
                if indeg[tr.to] == 0 {
                    order.push(tr.to);
                }
            }
        }
        debug_assert_eq!(order.len(), n, "cyclic transfer graph");
        dist.iter().copied().fold(0.0, f64::max)
    }
}

/// Request arrival pattern (paper §2.2: periodic sensors vs aperiodic
/// user-driven events; the paper's evaluation is periodic-only — aperiodic
/// support is the deferred extension).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Fixed-rate arrivals every `period` seconds (camera/microphone).
    Periodic,
    /// Poisson arrivals with mean inter-arrival `period` seconds
    /// (user-driven events), deterministic per seed.
    Poisson { seed: u64 },
}

/// A model group: networks fed by one synchronized input source, requested
/// every `period` seconds (paper §2.2 / §6.1).
#[derive(Debug, Clone)]
pub struct GroupSpec {
    /// Indices into the scenario's plan list.
    pub networks: Vec<usize>,
    pub period: f64,
    /// How requests arrive (defaults to periodic everywhere in the paper's
    /// protocol).
    pub pattern: ArrivalPattern,
}

impl GroupSpec {
    /// Periodic group (the paper's setting).
    pub fn periodic(networks: Vec<usize>, period: f64) -> GroupSpec {
        GroupSpec { networks, period, pattern: ArrivalPattern::Periodic }
    }

    /// Arrival timestamps for `n` requests under this group's pattern.
    pub fn arrival_times(&self, n: usize) -> Vec<f64> {
        let mut out = Vec::with_capacity(n);
        self.arrival_times_into(n, &mut out);
        out
    }

    /// Write the first `n` arrival timestamps into `out` (cleared first).
    /// Allocation-free once `out` has capacity — the simulator workspace
    /// reuses one scratch vector across runs.
    pub fn arrival_times_into(&self, n: usize, out: &mut Vec<f64>) {
        out.clear();
        match self.pattern {
            ArrivalPattern::Periodic => out.extend((0..n).map(|j| self.period * j as f64)),
            ArrivalPattern::Poisson { seed } => {
                let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
                let mut t = 0.0;
                out.extend((0..n).map(|_| {
                    // Exponential inter-arrival with mean `period`.
                    let u = rng.gen_f64().max(1e-12);
                    t += -self.period * u.ln();
                    t
                }));
            }
        }
    }
}

/// Simulator options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Requests to issue per model group.
    pub requests_per_group: usize,
    /// Use the zero-copy shared-buffer transfer cost (paper §5.3).
    pub zero_copy: bool,
    /// Per-task dispatch overhead on the coordinator path, seconds.
    pub dispatch_overhead: f64,
    /// Extra per-task allocation overhead when the tensor pool is disabled
    /// (constant + per-byte page-fault cost; reproduces Table 5's malloc /
    /// memcpy deltas at simulator granularity).
    pub tensor_pool: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            requests_per_group: 30,
            zero_copy: true,
            dispatch_overhead: 10e-6,
            tensor_pool: true,
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// `makespans[g][j]` = makespan of request `j` of group `g`, seconds.
    pub makespans: Vec<Vec<f64>>,
    /// Busy seconds per processor.
    pub busy: [f64; 3],
    /// Total simulated span, seconds.
    pub span: f64,
    /// Number of task executions simulated.
    pub tasks_run: usize,
}

impl SimResult {
    pub fn avg_makespan(&self, group: usize) -> f64 {
        let m = &self.makespans[group];
        if m.is_empty() { 0.0 } else { m.iter().sum::<f64>() / m.len() as f64 }
    }

    pub fn p90_makespan(&self, group: usize) -> f64 {
        percentile(&self.makespans[group], 0.90)
    }

    pub fn utilization(&self, p: Processor) -> f64 {
        if self.span <= 0.0 { 0.0 } else { self.busy[p.index()] / self.span }
    }
}

/// p-th percentile (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    let mut v = xs.to_vec();
    v.sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
    nearest_rank(&v, p)
}

/// Nearest-rank percentile of an already **sorted** slice (the shared
/// backend of [`percentile`] and [`SimWorkspace::p90_makespan`]).
pub(crate) fn nearest_rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((p * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[rank - 1]
}

/// Run the discrete-event simulation: compile the plans, run them through a
/// fresh [`SimWorkspace`], and return an owned [`SimResult`].
///
/// This is the convenience path (one compile + one workspace per call). Hot
/// loops — the GA's batch evaluator, the measurement tier — hold a
/// [`CompiledPlan`] set and a per-thread [`SimWorkspace`] and call
/// [`SimWorkspace::run`] directly, which allocates nothing in steady state.
pub fn simulate(
    plans: &[ExecutionPlan],
    groups: &[GroupSpec],
    comm: &CommModel,
    opts: &SimOptions,
) -> SimResult {
    let compiled = compile_plans(plans);
    let mut ws = SimWorkspace::new();
    ws.run(plans, &compiled, groups, comm, opts);
    ws.to_result()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn single_task_plan(duration: f64, p: Processor) -> ExecutionPlan {
        ExecutionPlan {
            tasks: vec![PlannedTask { duration, processor: p }],
            transfers: vec![],
            priority: 0,
        }
    }

    fn opts(n: usize) -> SimOptions {
        SimOptions { requests_per_group: n, dispatch_overhead: 0.0, ..Default::default() }
    }

    #[test]
    fn lone_task_makespan_is_duration() {
        let plans = [single_task_plan(0.010, Processor::Npu)];
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(5));
        for &m in &r.makespans[0] {
            assert!((m - 0.010).abs() < 1e-9, "makespan {m}");
        }
    }

    #[test]
    fn saturation_accumulates_backlog() {
        // Period shorter than duration: makespans must grow monotonically.
        let plans = [single_task_plan(0.010, Processor::Npu)];
        let groups = [GroupSpec::periodic(vec![0], 0.005)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(10));
        let m = &r.makespans[0];
        assert!(m[9] > m[0] + 0.04, "no backlog growth: {m:?}");
    }

    #[test]
    fn two_processors_run_in_parallel() {
        // Two independent single-task networks on different processors should
        // overlap: group makespan = max, not sum.
        let plans = [
            single_task_plan(0.010, Processor::Npu),
            single_task_plan(0.012, Processor::Gpu),
        ];
        let groups = [GroupSpec::periodic(vec![0, 1], 1.0)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(3));
        for &m in &r.makespans[0] {
            assert!((m - 0.012).abs() < 1e-6, "not parallel: {m}");
        }
    }

    #[test]
    fn same_processor_serializes() {
        let plans = [
            single_task_plan(0.010, Processor::Npu),
            single_task_plan(0.010, Processor::Npu),
        ];
        let groups = [GroupSpec::periodic(vec![0, 1], 1.0)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(2));
        for &m in &r.makespans[0] {
            assert!((m - 0.020).abs() < 1e-6, "not serialized: {m}");
        }
    }

    #[test]
    fn priority_orders_contending_networks() {
        // A long task occupies the NPU first (arrival order); the two
        // contenders then queue and must start in priority order.
        let mut blocker = single_task_plan(0.050, Processor::Npu);
        blocker.priority = 2;
        let mut a = single_task_plan(0.010, Processor::Npu);
        a.priority = 1;
        let mut b = single_task_plan(0.010, Processor::Npu);
        b.priority = 0;
        let plans = [blocker, a, b];
        let groups = [
            GroupSpec::periodic(vec![0], 1.0),
            GroupSpec::periodic(vec![1], 1.0),
            GroupSpec::periodic(vec![2], 1.0),
        ];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(1));
        // b (priority 0) preempts a in the queue: b at 60 ms, a at 70 ms.
        assert!(r.makespans[2][0] < r.makespans[1][0], "{:?}", r.makespans);
    }

    #[test]
    fn dependency_chain_with_transfer() {
        let plan = ExecutionPlan {
            tasks: vec![
                PlannedTask { duration: 0.005, processor: Processor::Npu },
                PlannedTask { duration: 0.005, processor: Processor::Gpu },
            ],
            transfers: vec![PlannedTransfer { from: 0, to: 1, bytes: 1 << 20 }],
            priority: 0,
        };
        let comm = CommModel::paper_calibrated();
        let expected_comm = comm.transfer_cost_zero_copy(1 << 20, false);
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let r = simulate(&[plan], &groups, &comm, &opts(1));
        let m = r.makespans[0][0];
        assert!((m - (0.010 + expected_comm)).abs() < 1e-7, "m={m}, comm={expected_comm}");
    }

    #[test]
    fn tensor_pool_off_costs_more() {
        let plans = [single_task_plan(0.001, Processor::Cpu)];
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let comm = CommModel::paper_calibrated();
        let with_pool = simulate(&plans, &groups, &comm, &SimOptions { requests_per_group: 3, ..Default::default() });
        let without = simulate(
            &plans,
            &groups,
            &comm,
            &SimOptions { requests_per_group: 3, tensor_pool: false, ..Default::default() },
        );
        assert!(without.avg_makespan(0) > with_pool.avg_makespan(0));
    }

    #[test]
    fn critical_path_lower_bounds_simulation() {
        let plan = ExecutionPlan {
            tasks: vec![
                PlannedTask { duration: 0.004, processor: Processor::Npu },
                PlannedTask { duration: 0.003, processor: Processor::Gpu },
                PlannedTask { duration: 0.002, processor: Processor::Npu },
            ],
            transfers: vec![
                PlannedTransfer { from: 0, to: 1, bytes: 4096 },
                PlannedTransfer { from: 1, to: 2, bytes: 4096 },
            ],
            priority: 0,
        };
        let comm = CommModel::paper_calibrated();
        let cp = plan.critical_path(&comm, true);
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let r = simulate(&[plan], &groups, &comm, &opts(1));
        assert!(r.makespans[0][0] >= cp - 1e-9);
    }

    #[test]
    fn utilization_bounded() {
        let plans = [single_task_plan(0.010, Processor::Npu)];
        let groups = [GroupSpec::periodic(vec![0], 0.02)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(10));
        let u = r.utilization(Processor::Npu);
        assert!(u > 0.3 && u <= 1.0 + 1e-9, "utilization {u}");
    }

    #[test]
    fn poisson_arrivals_are_deterministic_and_mean_matches() {
        let g = GroupSpec {
            networks: vec![0],
            period: 0.01,
            pattern: ArrivalPattern::Poisson { seed: 9 },
        };
        let a = g.arrival_times(500);
        let b = g.arrival_times(500);
        assert_eq!(a, b, "poisson arrivals must be deterministic per seed");
        // Strictly increasing; mean inter-arrival ~ period.
        assert!(a.windows(2).all(|w| w[1] > w[0]));
        let mean = a.last().unwrap() / a.len() as f64;
        assert!((mean / 0.01 - 1.0).abs() < 0.15, "mean inter-arrival {mean}");
    }

    #[test]
    fn aperiodic_simulation_completes_all_requests() {
        let plans = [single_task_plan(0.002, Processor::Npu)];
        let groups = [GroupSpec {
            networks: vec![0],
            period: 0.004,
            pattern: ArrivalPattern::Poisson { seed: 3 },
        }];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(25));
        assert_eq!(r.makespans[0].len(), 25);
        assert!(r.makespans[0].iter().all(|&m| m > 0.0));
    }

    #[test]
    fn bursty_arrivals_inflate_tail_makespans() {
        // Poisson bursts queue on the worker: the p90 makespan exceeds the
        // deterministic-arrival p90 at the same mean rate.
        let plans = [single_task_plan(0.003, Processor::Npu)];
        let periodic = simulate(
            &plans,
            &[GroupSpec::periodic(vec![0], 0.004)],
            &CommModel::paper_calibrated(),
            &opts(40),
        );
        let plans2 = [single_task_plan(0.003, Processor::Npu)];
        let bursty = simulate(
            &plans2,
            &[GroupSpec { networks: vec![0], period: 0.004, pattern: ArrivalPattern::Poisson { seed: 5 } }],
            &CommModel::paper_calibrated(),
            &opts(40),
        );
        assert!(
            bursty.p90_makespan(0) > periodic.p90_makespan(0),
            "bursty p90 {} <= periodic p90 {}",
            bursty.p90_makespan(0),
            periodic.p90_makespan(0)
        );
    }

    #[test]
    fn duration_overrides_equal_plan_durations_reproduce_run() {
        // run_with_durations with the plans' own durations must be
        // bit-identical to run() — the identity case of the measurement
        // tier's noisy-override path.
        let plans = [
            single_task_plan(0.010, Processor::Npu),
            single_task_plan(0.020, Processor::Gpu),
        ];
        let groups = [GroupSpec::periodic(vec![0, 1], 0.05)];
        let comm = CommModel::paper_calibrated();
        let o = opts(5);
        let compiled = compile_plans(&plans);
        let mut a = SimWorkspace::new();
        a.run(&plans, &compiled, &groups, &comm, &o);
        let ra = a.to_result();
        let durs: Vec<f64> =
            plans.iter().flat_map(|p| p.tasks.iter().map(|t| t.duration)).collect();
        let mut b = SimWorkspace::new();
        b.run_with_durations(&plans, &compiled, &durs, &groups, &comm, &o);
        let rb = b.to_result();
        assert_eq!(ra.makespans, rb.makespans);
        assert_eq!(ra.busy, rb.busy);
        assert_eq!(ra.span, rb.span);
        assert_eq!(ra.tasks_run, rb.tasks_run);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&xs, 0.90), 9.0);
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&[], 0.9), 0.0);
    }
}
