//! Discrete-event simulator of the Puzzle Runtime (paper §4.3).
//!
//! The paper uses a "simple simulator" (SimPy) that replicates runtime
//! behaviour — per-processor serial workers, subgraph dependencies,
//! communication costs, network priorities, periodic request arrivals — to
//! evaluate GA candidates cheaply during local search. This module rebuilds
//! that substrate as a fast event-driven simulator in rust: it is the GA's
//! inner-loop hot path (evaluated tens of thousands of times per search), so
//! it works on flat index-based structures with a binary-heap event queue.
//!
//! Inputs are [`ExecutionPlan`]s (one per network: subgraph durations from
//! the device-in-the-loop profiler, processor mapping, transfer byte counts)
//! plus [`GroupSpec`]s (model groups with periods). Output is the per-group
//! makespan series the XRBench metrics consume.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::comm::CommModel;
use crate::Processor;

/// One subgraph execution template within a network's plan.
#[derive(Debug, Clone)]
pub struct PlannedTask {
    /// Profiled (measured) execution duration, seconds.
    pub duration: f64,
    /// Worker that runs this subgraph.
    pub processor: Processor,
}

/// A tensor transfer between two subgraphs of the same network.
#[derive(Debug, Clone, Copy)]
pub struct PlannedTransfer {
    pub from: usize,
    pub to: usize,
    pub bytes: usize,
}

/// The executable plan for one network: its partitioned subgraphs, their
/// dependencies, and its scheduling priority (lower value = dispatched
/// first when competing for a worker).
#[derive(Debug, Clone)]
pub struct ExecutionPlan {
    pub tasks: Vec<PlannedTask>,
    pub transfers: Vec<PlannedTransfer>,
    pub priority: usize,
}

impl ExecutionPlan {
    /// Critical-path lower bound on one isolated request's latency
    /// (ignoring worker contention; used for sanity checks and seeds).
    pub fn critical_path(&self, comm: &CommModel, zero_copy: bool) -> f64 {
        let n = self.tasks.len();
        // Kahn order over the transfer DAG (subgraph ids are not guaranteed
        // to be topologically numbered).
        let mut indeg = vec![0usize; n];
        for tr in &self.transfers {
            indeg[tr.to] += 1;
        }
        let mut order: Vec<usize> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut head = 0;
        let mut dist = vec![0.0f64; n];
        while head < order.len() {
            let i = order[head];
            head += 1;
            dist[i] += self.tasks[i].duration;
            for tr in self.transfers.iter().filter(|t| t.from == i) {
                let same = self.tasks[tr.from].processor == self.tasks[tr.to].processor;
                let c = if zero_copy {
                    comm.transfer_cost_zero_copy(tr.bytes, same)
                } else {
                    comm.transfer_cost(tr.bytes, same)
                };
                dist[tr.to] = dist[tr.to].max(dist[i] + c);
                indeg[tr.to] -= 1;
                if indeg[tr.to] == 0 {
                    order.push(tr.to);
                }
            }
        }
        debug_assert_eq!(order.len(), n, "cyclic transfer graph");
        dist.iter().copied().fold(0.0, f64::max)
    }
}

/// Request arrival pattern (paper §2.2: periodic sensors vs aperiodic
/// user-driven events; the paper's evaluation is periodic-only — aperiodic
/// support is the deferred extension).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalPattern {
    /// Fixed-rate arrivals every `period` seconds (camera/microphone).
    Periodic,
    /// Poisson arrivals with mean inter-arrival `period` seconds
    /// (user-driven events), deterministic per seed.
    Poisson { seed: u64 },
}

/// A model group: networks fed by one synchronized input source, requested
/// every `period` seconds (paper §2.2 / §6.1).
#[derive(Debug, Clone)]
pub struct GroupSpec {
    /// Indices into the scenario's plan list.
    pub networks: Vec<usize>,
    pub period: f64,
    /// How requests arrive (defaults to periodic everywhere in the paper's
    /// protocol).
    pub pattern: ArrivalPattern,
}

impl GroupSpec {
    /// Periodic group (the paper's setting).
    pub fn periodic(networks: Vec<usize>, period: f64) -> GroupSpec {
        GroupSpec { networks, period, pattern: ArrivalPattern::Periodic }
    }

    /// Arrival timestamps for `n` requests under this group's pattern.
    pub fn arrival_times(&self, n: usize) -> Vec<f64> {
        match self.pattern {
            ArrivalPattern::Periodic => (0..n).map(|j| self.period * j as f64).collect(),
            ArrivalPattern::Poisson { seed } => {
                let mut rng = crate::util::rng::Rng::seed_from_u64(seed);
                let mut t = 0.0;
                (0..n)
                    .map(|_| {
                        // Exponential inter-arrival with mean `period`.
                        let u = rng.gen_f64().max(1e-12);
                        t += -self.period * u.ln();
                        t
                    })
                    .collect()
            }
        }
    }
}

/// Simulator options.
#[derive(Debug, Clone)]
pub struct SimOptions {
    /// Requests to issue per model group.
    pub requests_per_group: usize,
    /// Use the zero-copy shared-buffer transfer cost (paper §5.3).
    pub zero_copy: bool,
    /// Per-task dispatch overhead on the coordinator path, seconds.
    pub dispatch_overhead: f64,
    /// Extra per-task allocation overhead when the tensor pool is disabled
    /// (constant + per-byte page-fault cost; reproduces Table 5's malloc /
    /// memcpy deltas at simulator granularity).
    pub tensor_pool: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            requests_per_group: 30,
            zero_copy: true,
            dispatch_overhead: 10e-6,
            tensor_pool: true,
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// `makespans[g][j]` = makespan of request `j` of group `g`, seconds.
    pub makespans: Vec<Vec<f64>>,
    /// Busy seconds per processor.
    pub busy: [f64; 3],
    /// Total simulated span, seconds.
    pub span: f64,
    /// Number of task executions simulated.
    pub tasks_run: usize,
}

impl SimResult {
    pub fn avg_makespan(&self, group: usize) -> f64 {
        let m = &self.makespans[group];
        if m.is_empty() { 0.0 } else { m.iter().sum::<f64>() / m.len() as f64 }
    }

    pub fn p90_makespan(&self, group: usize) -> f64 {
        percentile(&self.makespans[group], 0.90)
    }

    pub fn utilization(&self, p: Processor) -> f64 {
        if self.span <= 0.0 { 0.0 } else { self.busy[p.index()] / self.span }
    }
}

/// p-th percentile (nearest-rank on a sorted copy).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p * v.len() as f64).ceil() as usize).clamp(1, v.len());
    v[rank - 1]
}

#[derive(Debug, Clone, Copy)]
enum Event {
    /// A periodic request arrives for a group.
    Arrival { group: usize, request: usize },
    /// A task instance finished on its worker.
    Complete { instance: usize },
    /// A task instance's inputs have landed on its worker (post-transfer).
    Ready { instance: usize },
}

/// Live state of one task instance (a subgraph execution for a specific
/// request of a specific network).
struct Instance {
    plan: usize,
    task: usize,
    group: usize,
    request: usize,
    remaining_deps: usize,
    /// (priority, arrival seq) dispatch key.
    priority: usize,
    seq: u64,
}

/// Heap entry carrying its event inline (§Perf L3-2: replaces the previous
/// payload-vector indirection and per-event allocation).
struct HeapEntry {
    time: f64,
    /// Completions sort ahead of arrivals at equal times so freed workers
    /// pick up backlog deterministically.
    class: u8,
    seq: u64,
    event: Event,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.class == other.class && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN time")
            .then(other.class.cmp(&self.class))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Run the discrete-event simulation.
pub fn simulate(
    plans: &[ExecutionPlan],
    groups: &[GroupSpec],
    comm: &CommModel,
    opts: &SimOptions,
) -> SimResult {
    let mut heap: BinaryHeap<HeapEntry> = BinaryHeap::new();
    let mut seq: u64 = 0;

    // Per-plan static metadata, computed once (§Perf L3-4: arrivals used to
    // re-scan the transfer list per task per request).
    struct PlanMeta {
        indeg: Vec<usize>,
        dependents: Vec<Vec<(usize, usize)>>, // task -> (dst task, bytes)
        in_bytes: Vec<usize>,
        roots: Vec<usize>,
    }
    let metas: Vec<PlanMeta> = plans
        .iter()
        .map(|plan| {
            let n = plan.tasks.len();
            let mut indeg = vec![0usize; n];
            let mut dependents: Vec<Vec<(usize, usize)>> = vec![Vec::new(); n];
            let mut in_bytes = vec![0usize; n];
            for tr in &plan.transfers {
                indeg[tr.to] += 1;
                in_bytes[tr.to] += tr.bytes;
                dependents[tr.from].push((tr.to, tr.bytes));
            }
            let roots = (0..n).filter(|&t| indeg[t] == 0).collect();
            PlanMeta { indeg, dependents, in_bytes, roots }
        })
        .collect();

    // Seed arrivals per the group's pattern.
    for (g, group) in groups.iter().enumerate() {
        for (j, t) in group.arrival_times(opts.requests_per_group).into_iter().enumerate() {
            seq += 1;
            heap.push(HeapEntry {
                time: t,
                class: 2,
                seq,
                event: Event::Arrival { group: g, request: j },
            });
        }
    }

    let mut instances: Vec<Instance> = Vec::new();
    let mut arrival_time: Vec<Vec<f64>> =
        groups.iter().map(|_| vec![0.0; opts.requests_per_group]).collect();
    let mut finish_time: Vec<Vec<f64>> =
        groups.iter().map(|_| vec![0.0; opts.requests_per_group]).collect();
    let mut open_tasks: Vec<Vec<usize>> =
        groups.iter().map(|_| vec![0; opts.requests_per_group]).collect();

    // Per-worker ready queues ordered by (priority, seq), carrying the
    // instance index directly.
    let mut ready: [BinaryHeap<Reverse<(usize, u64, usize)>>; 3] =
        [BinaryHeap::new(), BinaryHeap::new(), BinaryHeap::new()];
    let mut worker_busy = [false; 3];
    let mut busy_time = [0.0f64; 3];
    let mut tasks_run = 0usize;
    let mut span = 0.0f64;

    // Dependents of each instance: (dependent instance, bytes), consumed
    // once at completion.
    let mut dependents_of: Vec<Vec<(usize, usize)>> = Vec::new();

    let alloc_overhead = |bytes: usize| -> f64 {
        if opts.tensor_pool {
            0.0
        } else {
            // malloc + first-touch page faults (Table 5's memcpy inflation).
            8e-6 + bytes as f64 / 6.0e9
        }
    };

    macro_rules! start_if_free {
        ($p:expr, $now:expr) => {
            if !worker_busy[$p] {
                if let Some(Reverse((_, _, inst))) = ready[$p].pop() {
                    let i = &instances[inst];
                    let task = &plans[i.plan].tasks[i.task];
                    let in_bytes = metas[i.plan].in_bytes[i.task];
                    let dur = opts.dispatch_overhead
                        + alloc_overhead(task.duration as usize + in_bytes)
                        + task.duration;
                    worker_busy[$p] = true;
                    busy_time[$p] += dur;
                    tasks_run += 1;
                    seq += 1;
                    heap.push(HeapEntry {
                        time: $now + dur,
                        class: 0,
                        seq,
                        event: Event::Complete { instance: inst },
                    });
                }
            }
        };
    }

    while let Some(HeapEntry { time: now, event, .. }) = heap.pop() {
        span = span.max(now);
        match event {
            Event::Arrival { group, request } => {
                arrival_time[group][request] = now;
                for &net in &groups[group].networks {
                    let plan = &plans[net];
                    let meta = &metas[net];
                    let base = instances.len();
                    open_tasks[group][request] += plan.tasks.len();
                    for t in 0..plan.tasks.len() {
                        instances.push(Instance {
                            plan: net,
                            task: t,
                            group,
                            request,
                            remaining_deps: meta.indeg[t],
                            priority: plan.priority,
                            seq: base as u64 + t as u64,
                        });
                        // Shift this request's dependent edges to instance ids.
                        dependents_of.push(
                            meta.dependents[t]
                                .iter()
                                .map(|&(to, bytes)| (base + to, bytes))
                                .collect(),
                        );
                    }
                    // Root tasks are immediately ready.
                    for &t in &meta.roots {
                        let p = plan.tasks[t].processor.index();
                        let inst = &instances[base + t];
                        ready[p].push(Reverse((inst.priority, inst.seq, base + t)));
                        start_if_free!(p, now);
                    }
                }
            }
            Event::Complete { instance } => {
                let (plan_idx, task_idx, group, request) = {
                    let i = &instances[instance];
                    (i.plan, i.task, i.group, i.request)
                };
                let p = plans[plan_idx].tasks[task_idx].processor.index();
                worker_busy[p] = false;
                open_tasks[group][request] -= 1;
                finish_time[group][request] = finish_time[group][request].max(now);
                // Fan out to dependents, paying transfer cost per edge.
                let deps = std::mem::take(&mut dependents_of[instance]);
                for (dep_inst, bytes) in deps {
                    let dep = &mut instances[dep_inst];
                    dep.remaining_deps -= 1;
                    if dep.remaining_deps == 0 {
                        let from_p = plans[plan_idx].tasks[task_idx].processor;
                        let to_p = plans[dep.plan].tasks[dep.task].processor;
                        let same = from_p == to_p;
                        let c = if opts.zero_copy {
                            comm.transfer_cost_zero_copy(bytes, same)
                        } else {
                            comm.transfer_cost(bytes, same)
                        };
                        seq += 1;
                        heap.push(HeapEntry {
                            time: now + c,
                            class: 1,
                            seq,
                            event: Event::Ready { instance: dep_inst },
                        });
                    }
                }
                // Worker freed: start next ready task.
                start_if_free!(p, now);
            }
            Event::Ready { instance } => {
                let i = &instances[instance];
                let p = plans[i.plan].tasks[i.task].processor.index();
                ready[p].push(Reverse((i.priority, i.seq, instance)));
                start_if_free!(p, now);
            }
        }
    }

    let makespans = groups
        .iter()
        .enumerate()
        .map(|(g, _)| {
            (0..opts.requests_per_group)
                .map(|j| (finish_time[g][j] - arrival_time[g][j]).max(0.0))
                .collect()
        })
        .collect();

    SimResult { makespans, busy: busy_time, span, tasks_run }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn single_task_plan(duration: f64, p: Processor) -> ExecutionPlan {
        ExecutionPlan {
            tasks: vec![PlannedTask { duration, processor: p }],
            transfers: vec![],
            priority: 0,
        }
    }

    fn opts(n: usize) -> SimOptions {
        SimOptions { requests_per_group: n, dispatch_overhead: 0.0, ..Default::default() }
    }

    #[test]
    fn lone_task_makespan_is_duration() {
        let plans = [single_task_plan(0.010, Processor::Npu)];
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(5));
        for &m in &r.makespans[0] {
            assert!((m - 0.010).abs() < 1e-9, "makespan {m}");
        }
    }

    #[test]
    fn saturation_accumulates_backlog() {
        // Period shorter than duration: makespans must grow monotonically.
        let plans = [single_task_plan(0.010, Processor::Npu)];
        let groups = [GroupSpec::periodic(vec![0], 0.005)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(10));
        let m = &r.makespans[0];
        assert!(m[9] > m[0] + 0.04, "no backlog growth: {m:?}");
    }

    #[test]
    fn two_processors_run_in_parallel() {
        // Two independent single-task networks on different processors should
        // overlap: group makespan = max, not sum.
        let plans = [
            single_task_plan(0.010, Processor::Npu),
            single_task_plan(0.012, Processor::Gpu),
        ];
        let groups = [GroupSpec::periodic(vec![0, 1], 1.0)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(3));
        for &m in &r.makespans[0] {
            assert!((m - 0.012).abs() < 1e-6, "not parallel: {m}");
        }
    }

    #[test]
    fn same_processor_serializes() {
        let plans = [
            single_task_plan(0.010, Processor::Npu),
            single_task_plan(0.010, Processor::Npu),
        ];
        let groups = [GroupSpec::periodic(vec![0, 1], 1.0)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(2));
        for &m in &r.makespans[0] {
            assert!((m - 0.020).abs() < 1e-6, "not serialized: {m}");
        }
    }

    #[test]
    fn priority_orders_contending_networks() {
        // A long task occupies the NPU first (arrival order); the two
        // contenders then queue and must start in priority order.
        let mut blocker = single_task_plan(0.050, Processor::Npu);
        blocker.priority = 2;
        let mut a = single_task_plan(0.010, Processor::Npu);
        a.priority = 1;
        let mut b = single_task_plan(0.010, Processor::Npu);
        b.priority = 0;
        let plans = [blocker, a, b];
        let groups = [
            GroupSpec::periodic(vec![0], 1.0),
            GroupSpec::periodic(vec![1], 1.0),
            GroupSpec::periodic(vec![2], 1.0),
        ];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(1));
        // b (priority 0) preempts a in the queue: b at 60 ms, a at 70 ms.
        assert!(r.makespans[2][0] < r.makespans[1][0], "{:?}", r.makespans);
    }

    #[test]
    fn dependency_chain_with_transfer() {
        let plan = ExecutionPlan {
            tasks: vec![
                PlannedTask { duration: 0.005, processor: Processor::Npu },
                PlannedTask { duration: 0.005, processor: Processor::Gpu },
            ],
            transfers: vec![PlannedTransfer { from: 0, to: 1, bytes: 1 << 20 }],
            priority: 0,
        };
        let comm = CommModel::paper_calibrated();
        let expected_comm = comm.transfer_cost_zero_copy(1 << 20, false);
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let r = simulate(&[plan], &groups, &comm, &opts(1));
        let m = r.makespans[0][0];
        assert!((m - (0.010 + expected_comm)).abs() < 1e-7, "m={m}, comm={expected_comm}");
    }

    #[test]
    fn tensor_pool_off_costs_more() {
        let plans = [single_task_plan(0.001, Processor::Cpu)];
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let comm = CommModel::paper_calibrated();
        let with_pool = simulate(&plans, &groups, &comm, &SimOptions { requests_per_group: 3, ..Default::default() });
        let without = simulate(
            &plans,
            &groups,
            &comm,
            &SimOptions { requests_per_group: 3, tensor_pool: false, ..Default::default() },
        );
        assert!(without.avg_makespan(0) > with_pool.avg_makespan(0));
    }

    #[test]
    fn critical_path_lower_bounds_simulation() {
        let plan = ExecutionPlan {
            tasks: vec![
                PlannedTask { duration: 0.004, processor: Processor::Npu },
                PlannedTask { duration: 0.003, processor: Processor::Gpu },
                PlannedTask { duration: 0.002, processor: Processor::Npu },
            ],
            transfers: vec![
                PlannedTransfer { from: 0, to: 1, bytes: 4096 },
                PlannedTransfer { from: 1, to: 2, bytes: 4096 },
            ],
            priority: 0,
        };
        let comm = CommModel::paper_calibrated();
        let cp = plan.critical_path(&comm, true);
        let groups = [GroupSpec::periodic(vec![0], 1.0)];
        let r = simulate(&[plan], &groups, &comm, &opts(1));
        assert!(r.makespans[0][0] >= cp - 1e-9);
    }

    #[test]
    fn utilization_bounded() {
        let plans = [single_task_plan(0.010, Processor::Npu)];
        let groups = [GroupSpec::periodic(vec![0], 0.02)];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(10));
        let u = r.utilization(Processor::Npu);
        assert!(u > 0.3 && u <= 1.0 + 1e-9, "utilization {u}");
    }

    #[test]
    fn poisson_arrivals_are_deterministic_and_mean_matches() {
        let g = GroupSpec {
            networks: vec![0],
            period: 0.01,
            pattern: ArrivalPattern::Poisson { seed: 9 },
        };
        let a = g.arrival_times(500);
        let b = g.arrival_times(500);
        assert_eq!(a, b, "poisson arrivals must be deterministic per seed");
        // Strictly increasing; mean inter-arrival ~ period.
        assert!(a.windows(2).all(|w| w[1] > w[0]));
        let mean = a.last().unwrap() / a.len() as f64;
        assert!((mean / 0.01 - 1.0).abs() < 0.15, "mean inter-arrival {mean}");
    }

    #[test]
    fn aperiodic_simulation_completes_all_requests() {
        let plans = [single_task_plan(0.002, Processor::Npu)];
        let groups = [GroupSpec {
            networks: vec![0],
            period: 0.004,
            pattern: ArrivalPattern::Poisson { seed: 3 },
        }];
        let r = simulate(&plans, &groups, &CommModel::paper_calibrated(), &opts(25));
        assert_eq!(r.makespans[0].len(), 25);
        assert!(r.makespans[0].iter().all(|&m| m > 0.0));
    }

    #[test]
    fn bursty_arrivals_inflate_tail_makespans() {
        // Poisson bursts queue on the worker: the p90 makespan exceeds the
        // deterministic-arrival p90 at the same mean rate.
        let plans = [single_task_plan(0.003, Processor::Npu)];
        let periodic = simulate(
            &plans,
            &[GroupSpec::periodic(vec![0], 0.004)],
            &CommModel::paper_calibrated(),
            &opts(40),
        );
        let plans2 = [single_task_plan(0.003, Processor::Npu)];
        let bursty = simulate(
            &plans2,
            &[GroupSpec { networks: vec![0], period: 0.004, pattern: ArrivalPattern::Poisson { seed: 5 } }],
            &CommModel::paper_calibrated(),
            &opts(40),
        );
        assert!(
            bursty.p90_makespan(0) > periodic.p90_makespan(0),
            "bursty p90 {} <= periodic p90 {}",
            bursty.p90_makespan(0),
            periodic.p90_makespan(0)
        );
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0];
        assert_eq!(percentile(&xs, 0.90), 9.0);
        assert_eq!(percentile(&xs, 0.5), 5.0);
        assert_eq!(percentile(&[], 0.9), 0.0);
    }
}
