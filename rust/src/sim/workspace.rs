//! [`SimWorkspace`] — a reusable simulation arena: every buffer the
//! discrete-event loop needs (event heap, instance table, per-worker ready
//! queues, arrival/finish tables, scratch vectors), owned by one evaluator
//! thread and `reset()` between candidates.
//!
//! The seed `simulate()` allocated all of this per call — event heap,
//! instance vector, one dependent-list `Vec` *per task instance*, and the
//! makespan matrices — on a path the GA executes tens of thousands of times
//! per search. With a workspace, steady-state evaluation performs **zero**
//! heap allocation: containers are cleared (capacity retained), per-instance
//! dependent lists are gone entirely (the CSR arrays of
//! [`CompiledPlan`](super::CompiledPlan) are indexed through each instance's
//! block base), and objectives are read out of workspace buffers. The
//! guarantee is asserted by `rust/tests/batch_eval.rs` against the counting
//! allocator in [`crate::util::alloc`].
//!
//! Event ordering, tie-breaking, and floating-point accumulation order are
//! byte-for-byte identical to the seed implementation, so a reused workspace
//! reproduces fresh-allocation `simulate()` output exactly (also tested).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::comm::CommModel;

use super::{nearest_rank, CompiledPlan, ExecutionPlan, GroupSpec, SimOptions, SimResult};

#[derive(Debug, Clone, Copy)]
enum Event {
    /// A periodic request arrives for a group.
    Arrival { group: usize, request: usize },
    /// A task instance finished on its worker.
    Complete { instance: usize },
    /// A task instance's inputs have landed on its worker (post-transfer).
    Ready { instance: usize },
}

/// Live state of one task instance (a subgraph execution for a specific
/// request of a specific network).
struct Instance {
    plan: usize,
    task: usize,
    group: usize,
    request: usize,
    /// First instance index of this (network, request) block; dependent
    /// tasks of the same block live at `base + dep_task`.
    base: usize,
    remaining_deps: usize,
    /// (priority, arrival seq) dispatch key.
    priority: usize,
    seq: u64,
}

/// Heap entry carrying its event inline (§Perf L3-2: replaces the previous
/// payload-vector indirection and per-event allocation).
struct HeapEntry {
    time: f64,
    /// Completions sort ahead of arrivals at equal times so freed workers
    /// pick up backlog deterministically.
    class: u8,
    seq: u64,
    event: Event,
}

impl PartialEq for HeapEntry {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.class == other.class && self.seq == other.seq
    }
}
impl Eq for HeapEntry {}
impl PartialOrd for HeapEntry {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapEntry {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Reversed: BinaryHeap is a max-heap, we want earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .expect("NaN time")
            .then(other.class.cmp(&self.class))
            .then(other.seq.cmp(&self.seq))
    }
}

/// Reusable simulation state. Create once per evaluator thread, call
/// [`SimWorkspace::run`] per candidate, read objectives via the accessors.
pub struct SimWorkspace {
    heap: BinaryHeap<HeapEntry>,
    /// Per-worker ready queues ordered by (priority, seq), carrying the
    /// instance index directly.
    ready: [BinaryHeap<Reverse<(usize, u64, usize)>>; 3],
    instances: Vec<Instance>,
    /// Flat `[group * requests + j]` request arrival / finish times.
    arrival: Vec<f64>,
    finish: Vec<f64>,
    /// Scratch for per-group arrival timestamp generation.
    arrivals_scratch: Vec<f64>,
    /// Scratch for percentile computation (sorted copy of one group's
    /// makespans).
    sort_scratch: Vec<f64>,
    /// Per-plan offsets into a flat duration-override slice
    /// ([`SimWorkspace::run_with_durations`]).
    dur_base: Vec<usize>,
    busy: [f64; 3],
    span: f64,
    tasks_run: usize,
    n_groups: usize,
    requests: usize,
}

impl Default for SimWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl SimWorkspace {
    /// Empty workspace; buffers grow to steady-state capacity on first use.
    pub fn new() -> SimWorkspace {
        SimWorkspace {
            heap: BinaryHeap::new(),
            ready: [BinaryHeap::new(), BinaryHeap::new(), BinaryHeap::new()],
            instances: Vec::new(),
            arrival: Vec::new(),
            finish: Vec::new(),
            arrivals_scratch: Vec::new(),
            sort_scratch: Vec::new(),
            dur_base: Vec::new(),
            busy: [0.0; 3],
            span: 0.0,
            tasks_run: 0,
            n_groups: 0,
            requests: 0,
        }
    }

    fn reset(&mut self, n_groups: usize, requests: usize) {
        self.heap.clear();
        for q in &mut self.ready {
            q.clear();
        }
        self.instances.clear();
        let slots = n_groups * requests;
        self.arrival.clear();
        self.arrival.resize(slots, 0.0);
        self.finish.clear();
        self.finish.resize(slots, 0.0);
        self.busy = [0.0; 3];
        self.span = 0.0;
        self.tasks_run = 0;
        self.n_groups = n_groups;
        self.requests = requests;
    }

    /// Run the discrete-event simulation into this workspace. `compiled`
    /// must be the compilation of `plans` (structure only — durations are
    /// read from `plans`, so noisy-duration variants of the same plans can
    /// share one compilation).
    pub fn run(
        &mut self,
        plans: &[ExecutionPlan],
        compiled: &[CompiledPlan],
        groups: &[GroupSpec],
        comm: &CommModel,
        opts: &SimOptions,
    ) {
        self.run_inner(plans, compiled, groups, comm, opts, None)
    }

    /// [`SimWorkspace::run`] with a flat per-task duration override:
    /// `durations[base(p) + t]` replaces `plans[p].tasks[t].duration`, where
    /// `base(p)` is the total task count of plans `0..p`. Structure
    /// (dependencies, processors, transfers, priorities) still comes from
    /// `plans`/`compiled` — the measurement tier's noisy repetitions share
    /// one plan set and one compilation and vary **only** this slice,
    /// instead of cloning and rewriting whole plans per repetition. With
    /// `durations` equal to the plans' own durations, output is
    /// bit-identical to [`SimWorkspace::run`] (tested).
    pub fn run_with_durations(
        &mut self,
        plans: &[ExecutionPlan],
        compiled: &[CompiledPlan],
        durations: &[f64],
        groups: &[GroupSpec],
        comm: &CommModel,
        opts: &SimOptions,
    ) {
        debug_assert_eq!(
            durations.len(),
            plans.iter().map(|p| p.tasks.len()).sum::<usize>(),
            "one duration override per task"
        );
        self.run_inner(plans, compiled, groups, comm, opts, Some(durations))
    }

    fn run_inner(
        &mut self,
        plans: &[ExecutionPlan],
        compiled: &[CompiledPlan],
        groups: &[GroupSpec],
        comm: &CommModel,
        opts: &SimOptions,
        durs: Option<&[f64]>,
    ) {
        debug_assert_eq!(plans.len(), compiled.len());
        self.reset(groups.len(), opts.requests_per_group);
        let requests = opts.requests_per_group;

        // Split the workspace into disjoint field borrows so the event loop
        // below reads exactly like the seed implementation's locals.
        let SimWorkspace {
            heap, ready, instances, arrival, finish, arrivals_scratch, dur_base, ..
        } = self;
        dur_base.clear();
        let mut base_acc = 0usize;
        for p in plans {
            dur_base.push(base_acc);
            base_acc += p.tasks.len();
        }
        let task_duration = |plan: usize, task: usize| -> f64 {
            match durs {
                Some(d) => d[dur_base[plan] + task],
                None => plans[plan].tasks[task].duration,
            }
        };

        let mut seq: u64 = 0;
        let mut worker_busy = [false; 3];
        let mut busy_time = [0.0f64; 3];
        let mut tasks_run = 0usize;
        let mut span = 0.0f64;

        // Seed arrivals per the group's pattern.
        for (g, group) in groups.iter().enumerate() {
            group.arrival_times_into(requests, arrivals_scratch);
            for (j, &t) in arrivals_scratch.iter().enumerate() {
                seq += 1;
                heap.push(HeapEntry {
                    time: t,
                    class: 2,
                    seq,
                    event: Event::Arrival { group: g, request: j },
                });
            }
        }

        let alloc_overhead = |bytes: usize| -> f64 {
            if opts.tensor_pool {
                0.0
            } else {
                // malloc + first-touch page faults (Table 5's memcpy inflation).
                8e-6 + bytes as f64 / 6.0e9
            }
        };

        macro_rules! start_if_free {
            ($p:expr, $now:expr) => {
                if !worker_busy[$p] {
                    if let Some(Reverse((_, _, inst))) = ready[$p].pop() {
                        let i = &instances[inst];
                        let d = task_duration(i.plan, i.task);
                        let in_bytes = compiled[i.plan].in_bytes[i.task];
                        let dur = opts.dispatch_overhead
                            + alloc_overhead(d as usize + in_bytes)
                            + d;
                        worker_busy[$p] = true;
                        busy_time[$p] += dur;
                        tasks_run += 1;
                        seq += 1;
                        heap.push(HeapEntry {
                            time: $now + dur,
                            class: 0,
                            seq,
                            event: Event::Complete { instance: inst },
                        });
                    }
                }
            };
        }

        while let Some(HeapEntry { time: now, event, .. }) = heap.pop() {
            span = span.max(now);
            match event {
                Event::Arrival { group, request } => {
                    arrival[group * requests + request] = now;
                    for &net in &groups[group].networks {
                        let plan = &plans[net];
                        let cp = &compiled[net];
                        let base = instances.len();
                        for t in 0..plan.tasks.len() {
                            instances.push(Instance {
                                plan: net,
                                task: t,
                                group,
                                request,
                                base,
                                remaining_deps: cp.indeg[t],
                                priority: plan.priority,
                                seq: base as u64 + t as u64,
                            });
                        }
                        // Root tasks are immediately ready.
                        for &t in &cp.roots {
                            let p = plan.tasks[t].processor.index();
                            let inst = &instances[base + t];
                            ready[p].push(Reverse((inst.priority, inst.seq, base + t)));
                            start_if_free!(p, now);
                        }
                    }
                }
                Event::Complete { instance } => {
                    let (plan_idx, task_idx, group, request, base) = {
                        let i = &instances[instance];
                        (i.plan, i.task, i.group, i.request, i.base)
                    };
                    let from_p = plans[plan_idx].tasks[task_idx].processor;
                    let p = from_p.index();
                    worker_busy[p] = false;
                    let slot = group * requests + request;
                    finish[slot] = finish[slot].max(now);
                    // Fan out to dependents through the plan's CSR arrays,
                    // paying transfer cost per edge.
                    let cp = &compiled[plan_idx];
                    for k in cp.dep_range(task_idx) {
                        let dep_inst = base + cp.dep_task[k];
                        let bytes = cp.dep_bytes[k];
                        let dep = &mut instances[dep_inst];
                        dep.remaining_deps -= 1;
                        if dep.remaining_deps == 0 {
                            let to_p = plans[dep.plan].tasks[dep.task].processor;
                            let same = from_p == to_p;
                            let c = if opts.zero_copy {
                                comm.transfer_cost_zero_copy(bytes, same)
                            } else {
                                comm.transfer_cost(bytes, same)
                            };
                            seq += 1;
                            heap.push(HeapEntry {
                                time: now + c,
                                class: 1,
                                seq,
                                event: Event::Ready { instance: dep_inst },
                            });
                        }
                    }
                    // Worker freed: start next ready task.
                    start_if_free!(p, now);
                }
                Event::Ready { instance } => {
                    let i = &instances[instance];
                    let p = plans[i.plan].tasks[i.task].processor.index();
                    ready[p].push(Reverse((i.priority, i.seq, instance)));
                    start_if_free!(p, now);
                }
            }
        }

        self.busy = busy_time;
        self.span = span;
        self.tasks_run = tasks_run;
    }

    /// Makespan of request `j` of group `g` from the last run.
    #[inline]
    pub fn makespan(&self, g: usize, j: usize) -> f64 {
        let slot = g * self.requests + j;
        (self.finish[slot] - self.arrival[slot]).max(0.0)
    }

    /// Mean makespan of a group (matches [`SimResult::avg_makespan`]
    /// bit-for-bit: same values summed in the same order).
    pub fn avg_makespan(&self, g: usize) -> f64 {
        if self.requests == 0 {
            return 0.0;
        }
        let sum: f64 = (0..self.requests).map(|j| self.makespan(g, j)).sum();
        sum / self.requests as f64
    }

    /// 90th-percentile makespan of a group (nearest-rank, matching
    /// [`super::percentile`]). Uses the workspace sort scratch — no
    /// allocation in steady state.
    pub fn p90_makespan(&mut self, g: usize) -> f64 {
        self.sort_scratch.clear();
        for j in 0..self.requests {
            self.sort_scratch.push(self.makespan(g, j));
        }
        self.sort_scratch
            .sort_unstable_by(|a, b| a.partial_cmp(b).unwrap());
        nearest_rank(&self.sort_scratch, 0.90)
    }

    /// Write the analyzer's flattened `[avg, p90]` objectives per group into
    /// `out` (cleared first; no allocation once `out` has capacity).
    pub fn objectives_into(&mut self, out: &mut Vec<f64>) {
        out.clear();
        for g in 0..self.n_groups {
            out.push(self.avg_makespan(g));
            out.push(self.p90_makespan(g));
        }
    }

    /// Busy seconds of a processor from the last run.
    pub fn busy(&self, index: usize) -> f64 {
        self.busy[index]
    }

    /// Total simulated span of the last run, seconds.
    pub fn span(&self) -> f64 {
        self.span
    }

    /// Task executions simulated in the last run.
    pub fn tasks_run(&self) -> usize {
        self.tasks_run
    }

    /// Materialize the last run as an owned [`SimResult`] (allocates; the
    /// compatibility path behind [`super::simulate`]).
    pub fn to_result(&self) -> SimResult {
        let makespans = (0..self.n_groups)
            .map(|g| (0..self.requests).map(|j| self.makespan(g, j)).collect())
            .collect();
        SimResult { makespans, busy: self.busy, span: self.span, tasks_run: self.tasks_run }
    }
}
