//! [`CompiledPlan`] — per-plan structural metadata, derived **once per
//! decode** instead of on every `simulate()` call.
//!
//! The seed simulator rebuilt a `PlanMeta` (indegrees, per-task dependent
//! lists, input byte counts, root set) from the transfer list at the top of
//! every simulation; with the GA evaluating tens of thousands of candidates
//! per search, that rebuild — and its per-task `Vec` allocations — dominated
//! the inner loop. `CompiledPlan` flattens the same information into CSR
//! (compressed sparse row) arrays built exactly once, shared immutably by
//! every subsequent simulation of the plan (including the measurement tier's
//! noisy repetitions, whose perturbed durations leave the structure intact).
//!
//! Dependent edges preserve the transfer-list order per source task, so the
//! event sequence — and therefore every simulated makespan — is bit-identical
//! to the seed implementation.

use super::ExecutionPlan;

/// Flattened dependency structure of one [`ExecutionPlan`].
#[derive(Debug, Clone, Default)]
pub struct CompiledPlan {
    /// Number of tasks in the plan.
    pub(crate) n_tasks: usize,
    /// Incoming-transfer count per task.
    pub(crate) indeg: Vec<usize>,
    /// Total inbound transfer bytes per task (allocation-overhead model).
    pub(crate) in_bytes: Vec<usize>,
    /// Tasks with no dependencies — ready at request arrival.
    pub(crate) roots: Vec<usize>,
    /// CSR row offsets into `dep_task`/`dep_bytes`, length `n_tasks + 1`.
    pub(crate) dep_idx: Vec<usize>,
    /// Destination task of each dependent edge, grouped by source task.
    pub(crate) dep_task: Vec<usize>,
    /// Bytes carried by each dependent edge (parallel to `dep_task`).
    pub(crate) dep_bytes: Vec<usize>,
}

impl CompiledPlan {
    /// Compile a plan's transfer list into CSR dependency arrays.
    pub fn compile(plan: &ExecutionPlan) -> CompiledPlan {
        let n = plan.tasks.len();
        let mut indeg = vec![0usize; n];
        let mut in_bytes = vec![0usize; n];
        let mut counts = vec![0usize; n];
        for tr in &plan.transfers {
            indeg[tr.to] += 1;
            in_bytes[tr.to] += tr.bytes;
            counts[tr.from] += 1;
        }
        let mut dep_idx = vec![0usize; n + 1];
        for t in 0..n {
            dep_idx[t + 1] = dep_idx[t] + counts[t];
        }
        // Fill preserving transfer order per source (cursor sweep), matching
        // the seed's `dependents[from].push(..)` ordering exactly.
        let mut cursor: Vec<usize> = dep_idx[..n].to_vec();
        let m = plan.transfers.len();
        let mut dep_task = vec![0usize; m];
        let mut dep_bytes = vec![0usize; m];
        for tr in &plan.transfers {
            let c = cursor[tr.from];
            dep_task[c] = tr.to;
            dep_bytes[c] = tr.bytes;
            cursor[tr.from] += 1;
        }
        let roots = (0..n).filter(|&t| indeg[t] == 0).collect();
        CompiledPlan { n_tasks: n, indeg, in_bytes, roots, dep_idx, dep_task, dep_bytes }
    }

    /// Range of CSR edge indices whose source is `task`.
    #[inline]
    pub(crate) fn dep_range(&self, task: usize) -> std::ops::Range<usize> {
        self.dep_idx[task]..self.dep_idx[task + 1]
    }

    /// Dependent `(destination task, bytes)` pairs of `task`, in transfer
    /// order.
    pub fn dependents(&self, task: usize) -> impl Iterator<Item = (usize, usize)> + '_ {
        let r = self.dep_range(task);
        self.dep_task[r.clone()]
            .iter()
            .copied()
            .zip(self.dep_bytes[r].iter().copied())
    }

    /// Number of tasks in the compiled plan.
    pub fn num_tasks(&self) -> usize {
        self.n_tasks
    }
}

/// Compile every plan of a scenario (one-time cost per decode; memoized with
/// the decode itself by [`crate::ga::DecodedPlanCache`]).
pub fn compile_plans(plans: &[ExecutionPlan]) -> Vec<CompiledPlan> {
    plans.iter().map(CompiledPlan::compile).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{PlannedTask, PlannedTransfer};
    use crate::Processor;

    fn plan() -> ExecutionPlan {
        ExecutionPlan {
            tasks: (0..4)
                .map(|_| PlannedTask { duration: 0.001, processor: Processor::Npu })
                .collect(),
            transfers: vec![
                PlannedTransfer { from: 0, to: 1, bytes: 10 },
                PlannedTransfer { from: 0, to: 2, bytes: 20 },
                PlannedTransfer { from: 1, to: 3, bytes: 30 },
                PlannedTransfer { from: 2, to: 3, bytes: 40 },
            ],
            priority: 0,
        }
    }

    #[test]
    fn csr_mirrors_transfer_list() {
        let cp = CompiledPlan::compile(&plan());
        assert_eq!(cp.num_tasks(), 4);
        assert_eq!(cp.indeg, vec![0, 1, 1, 2]);
        assert_eq!(cp.in_bytes, vec![0, 10, 20, 70]);
        assert_eq!(cp.roots, vec![0]);
        let d0: Vec<(usize, usize)> = cp.dependents(0).collect();
        assert_eq!(d0, vec![(1, 10), (2, 20)], "transfer order preserved");
        let d3: Vec<(usize, usize)> = cp.dependents(3).collect();
        assert!(d3.is_empty());
    }

    #[test]
    fn empty_plan_compiles() {
        let cp = CompiledPlan::compile(&ExecutionPlan {
            tasks: vec![],
            transfers: vec![],
            priority: 0,
        });
        assert_eq!(cp.num_tasks(), 0);
        assert!(cp.roots.is_empty());
    }
}
