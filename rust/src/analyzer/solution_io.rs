//! Solution serialization — the hand-off the paper's Fig 2 shows between
//! the Static Analyzer and the Runtime ("the user selects the most
//! appropriate solution based on the use-case scenario, and submits it to
//! the Runtime").
//!
//! Format: a line-based text file (serde is unavailable offline), one
//! solution per `solution` block. The current version is **v3**:
//!
//! ```text
//! puzzle-solution v3
//! scenario <name>
//! groups <m,m,...> <m,m,...>        (one token per group; `-` = empty group)
//! hashes <h0> <h1> ...              (per-network structural Merkle root, hex)
//! solution <index>
//! objectives <o0> <o1> ...
//! network <idx> zoo <zoo_idx> priority <p>
//! cuts <0|1>...
//! mapping <C|G|N>...
//! end
//! ```
//!
//! v2 (the `Arc<PlanSet>`-era format) added the `groups` line — the model-
//! group membership (network indices per group) — so a file cannot be
//! replayed against a scenario whose group structure changed. v3 (this PR)
//! adds the `hashes` line: one [`merkle_hash_network`] fingerprint per
//! network, validated on load against the scenario's actual networks. That
//! closes the custom-model hole: [`crate::api::ScenarioSpec::Custom`]
//! networks serialize the `CUSTOM_ZOO_INDEX` sentinel, which the zoo check
//! cannot validate — the structural hash can. Plans are still *not*
//! serialized: genomes are re-decoded through the profiler at load time,
//! keeping files device-independent. **v1 (no `groups`) and v2 (no
//! `hashes`) files remain readable**; writing always produces v3.

use std::path::Path;

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use crate::ga::{Genome, NetworkGenes};
use crate::graph::merkle_hash_network;
use crate::scenario::Scenario;
use crate::Processor;

use super::Solution;

fn proc_char(p: Processor) -> char {
    match p {
        Processor::Cpu => 'C',
        Processor::Gpu => 'G',
        Processor::Npu => 'N',
    }
}

fn proc_from(c: char) -> Result<Processor> {
    Ok(match c {
        'C' => Processor::Cpu,
        'G' => Processor::Gpu,
        'N' => Processor::Npu,
        other => bail!("bad processor char {other:?}"),
    })
}

/// Serialize a set of analyzer solutions for a scenario (v3 format).
pub fn serialize_solutions(scenario: &Scenario, solutions: &[Solution]) -> String {
    let mut out = String::from("puzzle-solution v3\n");
    out.push_str(&format!("scenario {}\n", scenario.name));
    out.push_str("groups");
    for group in &scenario.groups {
        let members: Vec<String> = group.members.iter().map(|m| m.to_string()).collect();
        out.push(' ');
        if members.is_empty() {
            // An empty token would vanish under whitespace splitting on
            // parse; `-` keeps degenerate empty groups round-trippable.
            out.push('-');
        } else {
            out.push_str(&members.join(","));
        }
    }
    out.push('\n');
    // Per-network structural fingerprints (v3): validated on load, so a
    // file cannot be replayed against structurally different models even
    // when the zoo indices line up (custom models always do — they share
    // the CUSTOM_ZOO_INDEX sentinel).
    out.push_str("hashes");
    for net in &scenario.networks {
        out.push_str(&format!(" {}", merkle_hash_network(net)));
    }
    out.push('\n');
    for (si, sol) in solutions.iter().enumerate() {
        out.push_str(&format!("solution {si}\n"));
        out.push_str("objectives");
        for o in &sol.objectives {
            out.push_str(&format!(" {o}"));
        }
        out.push('\n');
        for (ni, genes) in sol.genome.networks.iter().enumerate() {
            out.push_str(&format!(
                "network {ni} zoo {} priority {}\n",
                scenario.zoo_indices[ni], sol.genome.priority[ni]
            ));
            out.push_str("cuts ");
            out.extend(genes.cuts.iter().map(|&c| if c { '1' } else { '0' }));
            out.push('\n');
            out.push_str("mapping ");
            out.extend(genes.mapping.iter().map(|&p| proc_char(p)));
            out.push('\n');
        }
        out.push_str("end\n");
    }
    out
}

/// A deserialized solution: genomes + objectives (plans are re-derived by
/// re-profiling at load time, keeping the file device-independent).
#[derive(Debug, Clone)]
pub struct LoadedSolution {
    pub genome: Genome,
    pub objectives: Vec<f64>,
}

/// Parse a solution file against a scenario (validates zoo indices and gene
/// lengths, so a stale file cannot be applied to the wrong scenario).
pub fn parse_solutions(text: &str, scenario: &Scenario) -> Result<Vec<LoadedSolution>> {
    let mut lines = text.lines();
    let header = lines.next().ok_or_else(|| anyhow!("empty solution file"))?;
    let version: u32 = match header {
        "puzzle-solution v1" => 1,
        "puzzle-solution v2" => 2,
        "puzzle-solution v3" => 3,
        other => bail!("unrecognized header {other:?}"),
    };
    let mut out = Vec::new();
    let mut groups_validated = version == 1; // v1 predates the groups line
    let mut hashes_validated = version < 3; // v1/v2 predate the hashes line
    let mut current: Option<(Vec<NetworkGenes>, Vec<usize>, Vec<f64>)> = None;
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("scenario") | None => {}
            Some("groups") => {
                if version == 1 {
                    bail!("groups directive in a v1 file");
                }
                let declared: Vec<Vec<usize>> = it
                    .map(|tok| {
                        if tok == "-" {
                            return Ok(Vec::new()); // empty group sentinel
                        }
                        tok.split(',')
                            .map(|m| m.parse::<usize>().context("bad group member"))
                            .collect::<Result<Vec<usize>>>()
                    })
                    .collect::<Result<_>>()?;
                let actual: Vec<Vec<usize>> =
                    scenario.groups.iter().map(|g| g.members.clone()).collect();
                if declared != actual {
                    bail!(
                        "solution was made for groups {declared:?}, scenario has {actual:?}"
                    );
                }
                groups_validated = true;
            }
            Some("hashes") => {
                if version < 3 {
                    bail!("hashes directive in a v{version} file");
                }
                let declared: Vec<u64> = it
                    .map(|tok| u64::from_str_radix(tok, 16).context("bad network hash"))
                    .collect::<Result<_>>()?;
                if declared.len() != scenario.networks.len() {
                    bail!(
                        "solution file declares {} network hashes, scenario has {} networks",
                        declared.len(),
                        scenario.networks.len()
                    );
                }
                for (ni, (&h, net)) in declared.iter().zip(&scenario.networks).enumerate() {
                    let actual = merkle_hash_network(net);
                    if actual.0 != h {
                        bail!(
                            "network {ni} ({}) structural hash mismatch: solution was made \
                             for {h:016x}, scenario network hashes to {actual}",
                            net.name
                        );
                    }
                }
                hashes_validated = true;
            }
            Some("solution") => {
                if current.is_some() {
                    bail!("nested solution block");
                }
                current = Some((Vec::new(), Vec::new(), Vec::new()));
            }
            Some("objectives") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("objectives outside block"))?;
                cur.2 = it
                    .map(|t| t.parse::<f64>().context("bad objective"))
                    .collect::<Result<_>>()?;
            }
            Some("network") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("network outside block"))?;
                let ni: usize = it.next().ok_or_else(|| anyhow!("missing idx"))?.parse()?;
                let kw_zoo = it.next();
                let zoo: usize = it.next().ok_or_else(|| anyhow!("missing zoo"))?.parse()?;
                let kw_prio = it.next();
                let prio: usize = it.next().ok_or_else(|| anyhow!("missing priority"))?.parse()?;
                if kw_zoo != Some("zoo") || kw_prio != Some("priority") {
                    bail!("malformed network line {line:?}");
                }
                if ni != cur.0.len() {
                    bail!("network index {ni} out of order");
                }
                if scenario.zoo_indices.get(ni) != Some(&zoo) {
                    bail!(
                        "solution was made for zoo model {zoo} at slot {ni}, scenario has {:?}",
                        scenario.zoo_indices.get(ni)
                    );
                }
                cur.0.push(NetworkGenes { cuts: Vec::new(), mapping: Vec::new() });
                cur.1.push(prio);
            }
            Some("cuts") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("cuts outside block"))?;
                let genes = cur.0.last_mut().ok_or_else(|| anyhow!("cuts before network"))?;
                let bits = it.next().unwrap_or("");
                genes.cuts = bits
                    .chars()
                    .map(|c| match c {
                        '0' => Ok(false),
                        '1' => Ok(true),
                        other => Err(anyhow!("bad cut bit {other:?}")),
                    })
                    .collect::<Result<_>>()?;
            }
            Some("mapping") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("mapping outside block"))?;
                let genes = cur.0.last_mut().ok_or_else(|| anyhow!("mapping before network"))?;
                let chars = it.next().unwrap_or("");
                genes.mapping = chars.chars().map(proc_from).collect::<Result<_>>()?;
            }
            Some("end") => {
                let (networks, priority, objectives) =
                    current.take().ok_or_else(|| anyhow!("end outside block"))?;
                let genome = Genome { networks, priority };
                if !genome.is_valid(&scenario.networks) {
                    bail!("solution genome invalid for scenario (gene lengths / priority)");
                }
                out.push(LoadedSolution { genome, objectives });
            }
            Some(other) => bail!("unknown directive {other:?}"),
        }
    }
    if current.is_some() {
        bail!("unterminated solution block");
    }
    if !groups_validated && !out.is_empty() {
        bail!("v{version} file is missing its groups line");
    }
    if !hashes_validated && !out.is_empty() {
        bail!("v{version} file is missing its hashes line");
    }
    Ok(out)
}

/// Save solutions to a file.
pub fn save_solutions(path: &Path, scenario: &Scenario, solutions: &[Solution]) -> Result<()> {
    std::fs::write(path, serialize_solutions(scenario, solutions))
        .with_context(|| format!("writing {}", path.display()))
}

/// Load solutions from a file, validated against the scenario.
pub fn load_solutions(path: &Path, scenario: &Scenario) -> Result<Vec<LoadedSolution>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    parse_solutions(&text, scenario)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{GaConfig, ScenarioSpec, SessionBuilder};

    fn analyzed() -> (Scenario, Vec<Solution>) {
        let session = SessionBuilder::new(ScenarioSpec::single_group("io", vec![0, 2]))
            .config(GaConfig::quick(13))
            .build()
            .unwrap();
        let analysis = session.run();
        (session.scenario().as_ref().clone(), analysis.pareto)
    }

    #[test]
    fn roundtrip_preserves_genomes_and_objectives() {
        let (scenario, sols) = analyzed();
        let text = serialize_solutions(&scenario, &sols);
        assert!(text.starts_with("puzzle-solution v3\n"), "writes the current version");
        assert!(text.contains("\ngroups 0,1\n"), "{text:.120}");
        assert!(text.contains("\nhashes "), "{text:.160}");
        let loaded = parse_solutions(&text, &scenario).unwrap();
        assert_eq!(loaded.len(), sols.len());
        for (a, b) in sols.iter().zip(&loaded) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.objectives, b.objectives);
        }
    }

    #[test]
    fn wrong_scenario_rejected() {
        let (scenario, sols) = analyzed();
        let text = serialize_solutions(&scenario, &sols);
        // Different models in the slots.
        let other = Scenario::from_groups("other", &[vec![5, 6]]);
        let err = parse_solutions(&text, &other).unwrap_err();
        assert!(err.to_string().contains("zoo model"), "{err}");
    }

    #[test]
    fn wrong_group_structure_rejected() {
        // Same zoo models in the same slots, but regrouped: the v2 groups
        // line must catch it (v1 could not).
        let (scenario, sols) = analyzed();
        let text = serialize_solutions(&scenario, &sols);
        let regrouped = Scenario::from_groups("io", &[vec![0], vec![2]]);
        let err = parse_solutions(&text, &regrouped).unwrap_err();
        assert!(err.to_string().contains("groups"), "{err}");
    }

    #[test]
    fn corrupted_inputs_rejected() {
        let (scenario, sols) = analyzed();
        let text = serialize_solutions(&scenario, &sols);
        for bad in [
            "bogus header\nrest",
            "puzzle-solution v2\nend\n",
            "puzzle-solution v1\ngroups 0,1\nend\n", // v1 must not carry groups
            "puzzle-solution v2\ngroups 0,1\nhashes 0\nend\n", // nor v2 hashes
            &text.replace("mapping N", "mapping X"),
            &text.replace("hashes ", "hashes f"), // corrupted fingerprint
            &text[..text.len() - 5],              // truncated
        ] {
            assert!(parse_solutions(bad, &scenario).is_err(), "accepted: {bad:.60}");
        }
        // A v3 file stripped of its hashes line is rejected outright.
        let stripped: String = text
            .lines()
            .filter(|l| !l.starts_with("hashes"))
            .map(|l| format!("{l}\n"))
            .collect();
        let err = parse_solutions(&stripped, &scenario).unwrap_err();
        assert!(err.to_string().contains("hashes"), "{err}");
    }

    #[test]
    fn custom_networks_validate_by_structural_hash() {
        use crate::api::{ScenarioSpec, SessionBuilder};
        // Two custom scenarios with identical shape metadata (group layout,
        // CUSTOM_ZOO_INDEX sentinels) but different network structure: only
        // the v3 hash line can tell them apart.
        let build_custom = |zoo_a: usize| {
            let nets =
                vec![crate::models::build_model(0, zoo_a), crate::models::build_model(1, 3)];
            SessionBuilder::new(ScenarioSpec::Custom {
                name: "cust".into(),
                networks: nets,
                groups: vec![vec![0, 1]],
            })
            .config(GaConfig { population: 10, max_generations: 3, ..GaConfig::quick(5) })
            .build()
            .unwrap()
        };
        let session = build_custom(0);
        let analysis = session.run();
        let scenario = session.scenario().as_ref();
        let text = serialize_solutions(scenario, &analysis.pareto);
        // Loads against the matching custom scenario…
        let loaded = parse_solutions(&text, scenario).unwrap();
        assert_eq!(loaded.len(), analysis.pareto.len());
        // …and is rejected by a structurally different one, despite both
        // declaring the same zoo sentinel in every slot.
        let other = build_custom(2);
        let err = parse_solutions(&text, other.scenario()).unwrap_err();
        assert!(err.to_string().contains("hash mismatch"), "{err}");
    }

    #[test]
    fn v2_fixture_still_loads() {
        // Back-compat: a checked-in v2 file (groups line, no hashes line)
        // parses against the matching scenario.
        let text = include_str!("../../tests/fixtures/solutions_v2.txt");
        let scenario = Scenario::from_groups("io", &[vec![0, 2]]);
        let loaded = parse_solutions(text, &scenario).unwrap();
        assert_eq!(loaded.len(), 1);
        assert!(loaded[0].genome.is_valid(&scenario.networks));
        assert_eq!(loaded[0].genome.priority, vec![1, 0]);
        // And still rejects a regrouped scenario (the v2 guarantee).
        let regrouped = Scenario::from_groups("io", &[vec![0], vec![2]]);
        assert!(parse_solutions(text, &regrouped).is_err());
    }

    #[test]
    fn v1_fixture_still_loads() {
        // Back-compat: a checked-in file written by the pre-session v1
        // serializer (no groups line) parses against the matching scenario.
        let text = include_str!("../../tests/fixtures/solutions_v1.txt");
        let scenario = Scenario::from_groups("io", &[vec![0, 2]]);
        let loaded = parse_solutions(text, &scenario).unwrap();
        assert_eq!(loaded.len(), 1);
        let sol = &loaded[0];
        assert!(sol.genome.is_valid(&scenario.networks));
        assert_eq!(sol.genome.priority, vec![1, 0]);
        assert_eq!(sol.objectives, vec![0.00375, 0.00411]);
        // And it migrates forward: re-serializing the loaded solution
        // produces a current-version file (groups + hashes lines included)
        // that parses back to the same genome against the same scenario.
        let migrated = Solution {
            genome: sol.genome.clone(),
            objectives: sol.objectives.clone(),
            plan_set: std::sync::Arc::new(crate::ga::PlanSet {
                plans: Vec::new(),
                compiled: Vec::new(),
            }),
        };
        let v3_text = serialize_solutions(&scenario, &[migrated]);
        assert!(v3_text.starts_with("puzzle-solution v3\n"));
        let reloaded = parse_solutions(&v3_text, &scenario).unwrap();
        assert_eq!(reloaded[0].genome, sol.genome);
        assert_eq!(reloaded[0].objectives, sol.objectives);
    }

    #[test]
    fn empty_group_roundtrips_via_sentinel() {
        // Degenerate scenarios (an empty model group) must save/load: the
        // `-` token keeps the group count under whitespace splitting.
        let scenario = Scenario::from_groups("deg", &[vec![0], vec![]]);
        let text = serialize_solutions(&scenario, &[]);
        assert!(text.contains("\ngroups 0 -\n"), "{text:.120}");
        assert!(parse_solutions(&text, &scenario).unwrap().is_empty());
        // ...and still mismatches a scenario without the empty group.
        let other = Scenario::from_groups("deg", &[vec![0]]);
        assert!(parse_solutions(&text, &other).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let (scenario, sols) = analyzed();
        let dir = std::env::temp_dir().join("puzzle_sol_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.txt");
        save_solutions(&path, &scenario, &sols).unwrap();
        let loaded = load_solutions(&path, &scenario).unwrap();
        assert_eq!(loaded.len(), sols.len());
        std::fs::remove_dir_all(&dir).ok();
    }
}
