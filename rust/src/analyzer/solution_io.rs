//! Solution serialization — the hand-off the paper's Fig 2 shows between
//! the Static Analyzer and the Runtime ("the user selects the most
//! appropriate solution based on the use-case scenario, and submits it to
//! the Runtime").
//!
//! Format: a line-based text file (serde is unavailable offline), one
//! solution per `solution` block:
//!
//! ```text
//! puzzle-solution v1
//! scenario <name>
//! solution <index>
//! objectives <o0> <o1> ...
//! network <idx> zoo <zoo_idx> priority <p>
//! cuts <0|1>...
//! mapping <C|G|N>...
//! end
//! ```

use std::path::Path;

use crate::util::error::{Context, Result};
use crate::{anyhow, bail};

use crate::ga::{Genome, NetworkGenes};
use crate::scenario::Scenario;
use crate::Processor;

use super::Solution;

fn proc_char(p: Processor) -> char {
    match p {
        Processor::Cpu => 'C',
        Processor::Gpu => 'G',
        Processor::Npu => 'N',
    }
}

fn proc_from(c: char) -> Result<Processor> {
    Ok(match c {
        'C' => Processor::Cpu,
        'G' => Processor::Gpu,
        'N' => Processor::Npu,
        other => bail!("bad processor char {other:?}"),
    })
}

/// Serialize a set of analyzer solutions for a scenario.
pub fn serialize_solutions(scenario: &Scenario, solutions: &[Solution]) -> String {
    let mut out = String::from("puzzle-solution v1\n");
    out.push_str(&format!("scenario {}\n", scenario.name));
    for (si, sol) in solutions.iter().enumerate() {
        out.push_str(&format!("solution {si}\n"));
        out.push_str("objectives");
        for o in &sol.objectives {
            out.push_str(&format!(" {o}"));
        }
        out.push('\n');
        for (ni, genes) in sol.genome.networks.iter().enumerate() {
            out.push_str(&format!(
                "network {ni} zoo {} priority {}\n",
                scenario.zoo_indices[ni], sol.genome.priority[ni]
            ));
            out.push_str("cuts ");
            out.extend(genes.cuts.iter().map(|&c| if c { '1' } else { '0' }));
            out.push('\n');
            out.push_str("mapping ");
            out.extend(genes.mapping.iter().map(|&p| proc_char(p)));
            out.push('\n');
        }
        out.push_str("end\n");
    }
    out
}

/// A deserialized solution: genomes + objectives (plans are re-derived by
/// re-profiling at load time, keeping the file device-independent).
#[derive(Debug, Clone)]
pub struct LoadedSolution {
    pub genome: Genome,
    pub objectives: Vec<f64>,
}

/// Parse a solution file against a scenario (validates zoo indices and gene
/// lengths, so a stale file cannot be applied to the wrong scenario).
pub fn parse_solutions(text: &str, scenario: &Scenario) -> Result<Vec<LoadedSolution>> {
    let mut lines = text.lines().peekable();
    let header = lines.next().ok_or_else(|| anyhow!("empty solution file"))?;
    if header != "puzzle-solution v1" {
        bail!("unrecognized header {header:?}");
    }
    let mut out = Vec::new();
    let mut current: Option<(Vec<NetworkGenes>, Vec<usize>, Vec<f64>)> = None;
    for line in lines {
        let mut it = line.split_whitespace();
        match it.next() {
            Some("scenario") | None => {}
            Some("solution") => {
                if current.is_some() {
                    bail!("nested solution block");
                }
                current = Some((Vec::new(), Vec::new(), Vec::new()));
            }
            Some("objectives") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("objectives outside block"))?;
                cur.2 = it
                    .map(|t| t.parse::<f64>().context("bad objective"))
                    .collect::<Result<_>>()?;
            }
            Some("network") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("network outside block"))?;
                let ni: usize = it.next().ok_or_else(|| anyhow!("missing idx"))?.parse()?;
                let kw_zoo = it.next();
                let zoo: usize = it.next().ok_or_else(|| anyhow!("missing zoo"))?.parse()?;
                let kw_prio = it.next();
                let prio: usize = it.next().ok_or_else(|| anyhow!("missing priority"))?.parse()?;
                if kw_zoo != Some("zoo") || kw_prio != Some("priority") {
                    bail!("malformed network line {line:?}");
                }
                if ni != cur.0.len() {
                    bail!("network index {ni} out of order");
                }
                if scenario.zoo_indices.get(ni) != Some(&zoo) {
                    bail!(
                        "solution was made for zoo model {zoo} at slot {ni}, scenario has {:?}",
                        scenario.zoo_indices.get(ni)
                    );
                }
                cur.0.push(NetworkGenes { cuts: Vec::new(), mapping: Vec::new() });
                cur.1.push(prio);
            }
            Some("cuts") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("cuts outside block"))?;
                let genes = cur.0.last_mut().ok_or_else(|| anyhow!("cuts before network"))?;
                let bits = it.next().unwrap_or("");
                genes.cuts = bits
                    .chars()
                    .map(|c| match c {
                        '0' => Ok(false),
                        '1' => Ok(true),
                        other => Err(anyhow!("bad cut bit {other:?}")),
                    })
                    .collect::<Result<_>>()?;
            }
            Some("mapping") => {
                let cur = current.as_mut().ok_or_else(|| anyhow!("mapping outside block"))?;
                let genes = cur.0.last_mut().ok_or_else(|| anyhow!("mapping before network"))?;
                let chars = it.next().unwrap_or("");
                genes.mapping = chars.chars().map(proc_from).collect::<Result<_>>()?;
            }
            Some("end") => {
                let (networks, priority, objectives) =
                    current.take().ok_or_else(|| anyhow!("end outside block"))?;
                let genome = Genome { networks, priority };
                if !genome.is_valid(&scenario.networks) {
                    bail!("solution genome invalid for scenario (gene lengths / priority)");
                }
                out.push(LoadedSolution { genome, objectives });
            }
            Some(other) => bail!("unknown directive {other:?}"),
        }
    }
    if current.is_some() {
        bail!("unterminated solution block");
    }
    Ok(out)
}

/// Save solutions to a file.
pub fn save_solutions(path: &Path, scenario: &Scenario, solutions: &[Solution]) -> Result<()> {
    std::fs::write(path, serialize_solutions(scenario, solutions))
        .with_context(|| format!("writing {}", path.display()))
}

/// Load solutions from a file, validated against the scenario.
pub fn load_solutions(path: &Path, scenario: &Scenario) -> Result<Vec<LoadedSolution>> {
    let text =
        std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
    parse_solutions(&text, scenario)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analyzer::{GaConfig, StaticAnalyzer};
    use crate::perf::PerfModel;

    fn analyzed() -> (Scenario, Vec<Solution>) {
        let scenario = Scenario::from_groups("io", &[vec![0, 2]]);
        let pm = PerfModel::paper_calibrated();
        let result = StaticAnalyzer::new(&scenario, &pm, GaConfig::quick(13)).run();
        (scenario, result.pareto)
    }

    #[test]
    fn roundtrip_preserves_genomes_and_objectives() {
        let (scenario, sols) = analyzed();
        let text = serialize_solutions(&scenario, &sols);
        let loaded = parse_solutions(&text, &scenario).unwrap();
        assert_eq!(loaded.len(), sols.len());
        for (a, b) in sols.iter().zip(&loaded) {
            assert_eq!(a.genome, b.genome);
            assert_eq!(a.objectives, b.objectives);
        }
    }

    #[test]
    fn wrong_scenario_rejected() {
        let (scenario, sols) = analyzed();
        let text = serialize_solutions(&scenario, &sols);
        // Different models in the slots.
        let other = Scenario::from_groups("other", &[vec![5, 6]]);
        let err = parse_solutions(&text, &other).unwrap_err();
        assert!(err.to_string().contains("zoo model"), "{err}");
    }

    #[test]
    fn corrupted_inputs_rejected() {
        let (scenario, sols) = analyzed();
        let text = serialize_solutions(&scenario, &sols);
        for bad in [
            "bogus header\nrest",
            "puzzle-solution v1\nend\n",
            &text.replace("mapping N", "mapping X"),
            &text[..text.len() - 5], // truncated
        ] {
            assert!(parse_solutions(bad, &scenario).is_err(), "accepted: {bad:.60}");
        }
    }

    #[test]
    fn file_roundtrip() {
        let (scenario, sols) = analyzed();
        let dir = std::env::temp_dir().join("puzzle_sol_io_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("s.txt");
        save_solutions(&path, &scenario, &sols).unwrap();
        let loaded = load_solutions(&path, &scenario).unwrap();
        assert_eq!(loaded.len(), sols.len());
        std::fs::remove_dir_all(&dir).ok();
    }
}
