//! The Static Analyzer (paper §4, Fig 4 & 8): Optimizer ↔ Simulator ↔
//! Runtime-Evaluator loop.
//!
//! Per generation: all parents reproduce (no elite selection), one-point /
//! UPMX crossover, mutation, probabilistic local search (simulator-scored,
//! accepted only on all-objective improvement), then candidate evaluation
//! and NSGA-III replacement. The stop rule is 3 generations without average
//! improvement, as in the paper.
//!
//! Two evaluation tiers mirror the paper:
//! * **simulation-based** — the fast discrete-event simulator, used inside
//!   local search and for the population objectives;
//! * **measurement-based** — "brief execution on the target device" before
//!   Pareto updates: a noisy re-evaluation (the calibrated noise model, or
//!   the real runtime in hardware mode) that demotes candidates whose
//!   simulated promise does not survive device fluctuation (the paper's
//!   Scenario-6 observation).
//!
//! ## Batch evaluation engine (§Perf)
//!
//! Candidate scoring — the search's entire cost — runs through a **batch
//! evaluator**: the initial population becomes [`EvalJob`]s, and each
//! generation's reproduction becomes [`PairJob`]s (parent indices + RNG
//! seeds derived *sequentially* from the master stream), which a
//! `std::thread::scope` fan-out processes in parallel. **Offspring
//! generation runs inside the fan-out too**: a pair job breeds its two
//! children (clone → one-point crossover → mutation, driven by the pair's
//! derived seed), then scores them (decode/memo, simulation, seed-driven
//! local search, measurement tier) on the same worker — the master thread
//! only draws seeds and gathers results by index. Each worker owns one
//! [`EvalScratch`] (reusable [`SimWorkspace`], partition/probe arenas,
//! measurement-tier buffers, local-search clone target) and shares the
//! [`DecodedPlanCache`] genome→plan memo and the merkle-keyed profile DB.
//! Because every job's outcome depends only on its parents and its derived
//! seeds — never on cross-thread state — results gathered back by index are
//! **bit-identical for any thread count**, including `threads = 1` (tested
//! by `deterministic_across_thread_counts` and
//! `offspring_fanout_deterministic_with_odd_population`). Only the profiler/memo
//! hit-miss *counters* may vary under concurrency (two threads can race the
//! same miss); objectives, Pareto fronts, and evaluation counts never do.
//!
//! Replacement runs through [`SelectionWorkspace`] — ENS non-dominated
//! sorting + binary-heap niching, bit-identical to `nsga3_select` — with
//! the flattened objective matrix and survivor index list kept in reusable
//! master-thread buffers, so per-generation selection allocates nothing in
//! steady state. The solutions replacement drops donate their genome and
//! objectives buffers to a free-list slab; the next generation's pair jobs
//! pop those buffers and breed into them ([`crate::ga::breed_pair_into`],
//! identical RNG stream to the cloning path), so once the search is warm a
//! generation's reproduction and retention recycle the previous
//! generation's casualties instead of allocating fresh genome storage
//! (tested allocation-free by `recycled_breed_and_eval_is_allocation_free`).
//!
//! The measurement tier is **vectorized across repetitions**: nominal
//! durations and processors are flattened once per candidate, each rep
//! samples multiplicative noise factors in one flat pass
//! ([`crate::perf::PerfModel::sample_factor`]) and replays the shared
//! compiled plan through [`SimWorkspace::run_with_durations`] — no plan
//! cloning per candidate, no per-rep plan rewriting.
//!
//! ## Entry points (§API, this PR)
//!
//! External callers drive the analyzer through the owned session layer in
//! [`crate::api`]: a [`crate::api::SessionBuilder`] yields an
//! `AnalysisSession` whose `run`/`run_observed` stream per-generation
//! progress and return an `Analysis` that deploys straight to a
//! [`crate::coordinator::Coordinator`]. The borrow-based
//! [`StaticAnalyzer::new`]/[`StaticAnalyzer::run`] remain as deprecated
//! shims. Solutions share their decoded plans via [`Arc<PlanSet>`] — Pareto
//! bookkeeping moves candidates instead of deep-cloning their
//! `Vec<ExecutionPlan>`.

pub mod solution_io;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::util::rng::Rng;

use crate::comm::CommModel;
use crate::ga::{
    breed_pair_into, decode, fast_non_dominated_sort, merge_neighbors_into,
    reposition_adjacent_into, DecodeScratch, DecodedPlanCache, Genome, MutationRates, PlanSet,
    SelectionWorkspace, UpmxScratch,
};

use crate::perf::PerfModel;
use crate::profiler::Profiler;
use crate::scenario::Scenario;
use crate::sim::{simulate, ExecutionPlan, GroupSpec, SimOptions, SimWorkspace};
use crate::Processor;

/// Analyzer hyper-parameters.
#[derive(Debug, Clone)]
pub struct GaConfig {
    pub population: usize,
    pub max_generations: usize,
    /// Stop after this many generations without average improvement
    /// (paper: 3).
    pub patience: usize,
    pub cut_prob_init: f64,
    pub p_mutate_cut: f64,
    pub p_mutate_map: f64,
    pub p_mutate_prio: f64,
    /// Probability of attempting local search on a fresh child.
    pub p_local_search: f64,
    /// Requests per group when simulating a candidate.
    pub sim_requests: usize,
    pub seed: u64,
    /// Number of noisy "brief execution" repetitions in the measurement
    /// tier (0 disables the tier).
    pub measure_reps: usize,
    /// Explore the partition chromosome (ablation switch: off freezes all
    /// networks whole, reducing the search to mapping+priority — the Kang
    /// et al. / Best-Mapping regime the paper compares against).
    pub explore_partition: bool,
    /// Explore the priority chromosome (off pins the identity order).
    pub explore_priority: bool,
    /// Evaluator threads for batch candidate scoring. `0` = one per
    /// available core. Results are identical for every value (the
    /// determinism contract above); `1` forces the serial path.
    pub threads: usize,
    /// Shared core budget for the evaluation fan-out. When set, every
    /// generation leases its worker count from the budget (superseding
    /// `threads` — the lease alone bounds the width, so freed cores from
    /// sibling fan-outs are reclaimed generation by generation). Results
    /// are bit-identical for any budget: the width changes scheduling
    /// only, exactly as with `threads`.
    pub core_budget: Option<crate::util::threads::CoreBudget>,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 48,
            max_generations: 40,
            patience: 3,
            cut_prob_init: 0.15,
            p_mutate_cut: 0.03,
            p_mutate_map: 0.06,
            p_mutate_prio: 0.30,
            p_local_search: 0.35,
            sim_requests: 20,
            seed: 23,
            measure_reps: 3,
            explore_partition: true,
            explore_priority: true,
            threads: 0,
            core_budget: None,
        }
    }
}

impl GaConfig {
    /// A reduced-budget config for tests and examples.
    pub fn quick(seed: u64) -> GaConfig {
        GaConfig {
            population: 24,
            max_generations: 14,
            sim_requests: 10,
            measure_reps: 2,
            seed,
            ..Default::default()
        }
    }
}

/// One evaluated candidate.
///
/// The decoded plans are held as a shared [`Arc<PlanSet>`] (one decode per
/// genome, owned by the [`DecodedPlanCache`]): cloning a `Solution` — Pareto
/// archive updates, survivor carry-over, deployment hand-off — never copies
/// the underlying `Vec<ExecutionPlan>` (the per-candidate deep clone this
/// replaced was the analyzer's dominant steady-state allocation).
#[derive(Debug, Clone)]
pub struct Solution {
    pub genome: Genome,
    /// Minimized objectives: `[avg makespan, p90 makespan]` per group,
    /// flattened (paper: "average and 90th percentile of makespans for each
    /// model group").
    pub objectives: Vec<f64>,
    /// Decoded plans + one-time structural compilation, shared across every
    /// clone of this solution (and with the decode memo).
    pub plan_set: Arc<PlanSet>,
}

impl Solution {
    /// The executable per-network plans of this solution.
    pub fn plans(&self) -> &[ExecutionPlan] {
        &self.plan_set.plans
    }

    /// Worst (maximum) objective — the paper's single-number selection
    /// metric ("the smallest maximum makespan", §5.3).
    pub fn max_objective(&self) -> f64 {
        self.objectives.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Analyzer output: the Pareto archive and search telemetry.
#[derive(Debug, Clone)]
pub struct AnalysisResult {
    pub pareto: Vec<Solution>,
    pub generations_run: usize,
    pub evaluations: usize,
    pub profile_cache_hits: u64,
    pub profile_measurements: u64,
    /// Genome→plan memo hits (decodes skipped entirely).
    pub plan_cache_hits: u64,
    /// Actual decode + compile executions.
    pub plan_cache_misses: u64,
    /// True when an [`crate::api::Observer`] hook returned
    /// [`std::ops::ControlFlow::Break`]: the Pareto front reflects the
    /// population at the moment of cancellation, not convergence.
    pub cancelled: bool,
}

impl AnalysisResult {
    /// The solution minimizing the maximum (worst-group) average makespan —
    /// the paper's selection rule for single-number comparisons ("choosing
    /// the solution with the smallest maximum makespan", §5.3).
    pub fn best_by_max_makespan(&self) -> &Solution {
        self.pareto
            .iter()
            .min_by(|a, b| a.max_objective().partial_cmp(&b.max_objective()).unwrap())
            .expect("non-empty pareto set")
    }
}

/// One unit of batch-evaluation work: a candidate genome plus the RNG seed
/// that drives its local-search decisions and measurement-tier noise. Seeds
/// are drawn sequentially from the master stream *before* the parallel
/// fan-out, which is what makes results thread-count independent. The
/// genome is *moved* into the resulting [`Solution`] (via `mem::take`), so
/// scoring a job never copies it; `obj` is the recycled objectives buffer
/// the resulting [`Solution`] takes over.
struct EvalJob {
    genome: Genome,
    obj: Vec<f64>,
    seed: u64,
    local_search: bool,
    measure: bool,
}

/// One unit of offspring work: breed the parent pair `(a, b)` (clone →
/// crossover → mutation, driven by `pair_seed`) and evaluate the children
/// with `seed_a`/`seed_b` — the whole reproduction step of one pair, shipped
/// to a worker thread. All three seeds are drawn sequentially from the
/// master stream before the fan-out, so the children are a pure function of
/// `(parents, seeds)` whatever the thread count. `emit_b` is false only for
/// the surplus child of an odd-population last pair.
///
/// The job carries the buffers its children will live in: `out_a`/`out_b`
/// genomes and `obj_a`/`obj_b` objective vectors, popped from the
/// replacement slab (survivors of the last NSGA-III replacement recycled
/// via [`take_by_index_into`]). Breeding writes into them with the
/// buffer-reusing [`breed_pair_into`], so steady-state reproduction
/// allocates no genome or objective storage at all. An unused `out_b` /
/// `obj_b` (the `!emit_b` pair) stays in the job for the master thread to
/// harvest back into the slab.
struct PairJob {
    a: usize,
    b: usize,
    pair_seed: u64,
    seed_a: u64,
    seed_b: u64,
    emit_b: bool,
    measure: bool,
    out_a: Genome,
    out_b: Genome,
    obj_a: Vec<f64>,
    obj_b: Vec<f64>,
}

/// Per-worker evaluation scratch: simulation arena, first-touch decode
/// arenas (partitioning + config probing), the measurement tier's flat
/// duration/factor buffers, objective buffers, and the local-search clone
/// target. One per evaluator thread; with it, steady-state candidate
/// scoring allocates only for each [`Solution`]'s owned output (genome
/// already moved in, one objectives `Vec`) and whatever the shared caches
/// store on a miss.
#[derive(Default)]
struct EvalScratch {
    sim: SimWorkspace,
    decode: DecodeScratch,
    /// Flat nominal duration per task of the current candidate's plan set.
    nominal: Vec<f64>,
    /// Flat processor per task (parallel to `nominal`).
    procs: Vec<Processor>,
    /// Flat noisy durations of the current measurement repetition.
    durs: Vec<f64>,
    /// Worst-observed `[avg, p90]` per group across repetitions.
    worst: Vec<f64>,
    /// Objectives of the job's current best genome.
    objectives: Vec<f64>,
    /// Objectives of the local-search candidate under test.
    cand_objectives: Vec<f64>,
    /// Local-search candidate clone target (buffer-reusing `clone_from`).
    cand: Genome,
    /// UPMX position-index buffers for [`crate::ga::breed_pair_into`] (the
    /// last per-pair allocations of the offspring fan-out).
    upmx: UpmxScratch,
}

/// Shared, thread-safe evaluation context: the profile DB, the genome→plan
/// memo, the group specs, and the evaluation counter. Everything here is
/// value-deterministic under concurrent access (see module docs).
struct EvalCtx<'a, 'd> {
    profiler: &'a Profiler<'d>,
    cache: &'a DecodedPlanCache,
    groups: &'a [GroupSpec],
    evals: &'a AtomicUsize,
}

/// The Static Analyzer.
pub struct StaticAnalyzer<'a> {
    pub scenario: &'a Scenario,
    pub perf: &'a PerfModel,
    pub comm: CommModel,
    pub config: GaConfig,
    /// Period per group at the search multiplier (paper searches at α = 1).
    pub periods: Vec<f64>,
}

impl<'a> StaticAnalyzer<'a> {
    /// Internal constructor: the engine behind [`crate::api::AnalysisSession`]
    /// (which owns the scenario/perf data this borrows for the duration of a
    /// run).
    pub(crate) fn engine(scenario: &'a Scenario, perf: &'a PerfModel, config: GaConfig) -> Self {
        let periods = scenario.periods(1.0, perf);
        StaticAnalyzer {
            scenario,
            perf,
            comm: CommModel::paper_calibrated(),
            config,
            periods,
        }
    }

    /// Deprecated borrow-based entry point. Prefer
    /// [`crate::api::SessionBuilder`], which owns its inputs and exposes the
    /// whole analyze → deploy flow.
    #[deprecated(
        since = "0.2.0",
        note = "use puzzle::api::SessionBuilder to construct an AnalysisSession"
    )]
    pub fn new(scenario: &'a Scenario, perf: &'a PerfModel, config: GaConfig) -> Self {
        Self::engine(scenario, perf, config)
    }

    /// Group specs at the search-time periods.
    pub fn groups(&self) -> Vec<GroupSpec> {
        self.scenario
            .groups
            .iter()
            .zip(&self.periods)
            .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
            .collect()
    }

    /// Simulate one genome → flattened `[avg, p90]` objectives per group.
    /// Serial convenience path (tests, one-off scoring); the search itself
    /// goes through [`Self::run`]'s batch evaluator.
    pub fn evaluate(
        &self,
        genome: &Genome,
        profiler: &Profiler<'_>,
        groups: &[GroupSpec],
    ) -> (Vec<f64>, Vec<ExecutionPlan>) {
        let plans = decode(&self.scenario.networks, genome, profiler, &self.comm);
        let opts = self.sim_opts();
        let result = simulate(&plans, groups, &self.comm, &opts);
        let mut objectives = Vec::with_capacity(groups.len() * 2);
        for g in 0..groups.len() {
            objectives.push(result.avg_makespan(g));
            objectives.push(result.p90_makespan(g));
        }
        (objectives, plans)
    }

    fn sim_opts(&self) -> SimOptions {
        SimOptions { requests_per_group: self.config.sim_requests, ..Default::default() }
    }

    /// Memoized evaluation through the shared plan cache and the per-thread
    /// scratch: decode (or memo-hit), simulate allocation-free, write the
    /// objectives into `out` (cleared first).
    fn evaluate_cached(
        &self,
        genome: &Genome,
        ctx: &EvalCtx<'_, '_>,
        sim: &mut SimWorkspace,
        decode: &mut DecodeScratch,
        out: &mut Vec<f64>,
    ) -> Arc<PlanSet> {
        let set = ctx.cache.decode_scratch(
            &self.scenario.networks,
            genome,
            ctx.profiler,
            &self.comm,
            decode,
        );
        let opts = self.sim_opts();
        sim.run(&set.plans, &set.compiled, ctx.groups, &self.comm, &opts);
        sim.objectives_into(out);
        ctx.evals.fetch_add(1, Ordering::Relaxed);
        set
    }

    /// Measurement tier: re-evaluate with execution-time noise, scoring by
    /// the worst observed repetition (written into `worst` as flattened
    /// `[avg, p90]` per group). Candidates that only look good in the
    /// noiseless simulation get demoted here.
    ///
    /// Vectorized across repetitions: the candidate's nominal durations and
    /// processors are flattened once, each rep samples multiplicative noise
    /// factors in one flat pass ([`PerfModel::sample_factor`] — bit-equal to
    /// the per-task `sample` rewrite it replaces, same RNG stream) and
    /// replays the shared compilation via
    /// [`SimWorkspace::run_with_durations`]. No plan clones, no per-rep
    /// plan rewriting, zero steady-state allocation.
    #[allow(clippy::too_many_arguments)]
    fn measure_with(
        &self,
        set: &PlanSet,
        ctx: &EvalCtx<'_, '_>,
        rng: &mut Rng,
        sim: &mut SimWorkspace,
        nominal: &mut Vec<f64>,
        procs: &mut Vec<Processor>,
        durs: &mut Vec<f64>,
        worst: &mut Vec<f64>,
    ) {
        let opts = self.sim_opts();
        worst.clear();
        worst.resize(ctx.groups.len() * 2, 0.0);
        nominal.clear();
        procs.clear();
        for plan in &set.plans {
            for t in &plan.tasks {
                nominal.push(t.duration);
                procs.push(t.processor);
            }
        }
        durs.clear();
        durs.resize(nominal.len(), 0.0);
        for _ in 0..self.config.measure_reps.max(1) {
            for i in 0..nominal.len() {
                durs[i] = nominal[i] * self.perf.sample_factor(procs[i], rng);
            }
            sim.run_with_durations(&set.plans, &set.compiled, durs, ctx.groups, &self.comm, &opts);
            for g in 0..ctx.groups.len() {
                worst[g * 2] = worst[g * 2].max(sim.avg_makespan(g));
                worst[g * 2 + 1] = worst[g * 2 + 1].max(sim.p90_makespan(g));
            }
        }
    }

    /// Score one candidate end-to-end: memoized evaluation, seed-driven
    /// local search (in-place moves into the scratch clone target, accepted
    /// only on all-objective improvement), measurement tier. Everything the
    /// job touches is either its own (`rng` from the derived seed, the
    /// thread-local scratch) or value-deterministic shared state (profile
    /// DB, plan memo), so the result is a pure function of (genome, seed).
    /// The genome is owned and moves into the returned [`Solution`], as
    /// does `obj_out` — a recycled objectives buffer (cleared and refilled
    /// here) so scoring a job with slab-recycled inputs allocates nothing
    /// for the solution's own storage.
    #[allow(clippy::too_many_arguments)]
    fn eval_one(
        &self,
        genome: Genome,
        mut obj_out: Vec<f64>,
        seed: u64,
        local_search: bool,
        measure: bool,
        ctx: &EvalCtx<'_, '_>,
        scratch: &mut EvalScratch,
    ) -> Solution {
        let EvalScratch {
            sim,
            decode,
            nominal,
            procs,
            durs,
            worst,
            objectives,
            cand_objectives,
            cand,
        } = scratch;
        let mut genome = genome;
        let mut set = self.evaluate_cached(&genome, ctx, sim, decode, objectives);
        if local_search || measure {
            let mut rng = Rng::seed_from_u64(seed);
            if local_search && rng.gen_bool(self.config.p_local_search) {
                let nets = &self.scenario.networks;
                for _ in 0..2 {
                    let moved = if rng.gen_bool(0.5) {
                        merge_neighbors_into(&genome, cand, &mut rng)
                    } else {
                        reposition_adjacent_into(nets, &genome, cand, &mut rng)
                    };
                    if moved {
                        let cset = self.evaluate_cached(cand, ctx, sim, decode, cand_objectives);
                        let better_all = cand_objectives
                            .iter()
                            .zip(objectives.iter())
                            .all(|(c, o)| c <= o)
                            && cand_objectives.iter().zip(objectives.iter()).any(|(c, o)| c < o);
                        if better_all {
                            std::mem::swap(&mut genome, cand);
                            std::mem::swap(objectives, cand_objectives);
                            set = cset;
                        }
                    }
                }
            }
            if measure {
                self.measure_with(&set, ctx, &mut rng, sim, nominal, procs, durs, worst);
                objectives.clear();
                objectives.extend_from_slice(worst);
            }
        }
        obj_out.clear();
        obj_out.extend_from_slice(objectives);
        Solution { genome, objectives: obj_out, plan_set: set }
    }

    /// Breed one pair job and evaluate its children on the calling worker
    /// thread: derive the pair RNG, breed the parents into the job's
    /// recycled genome buffers (copy-into → crossover → mutation), apply
    /// the ablation switches, then score each emitted child with its own
    /// derived seed. The `!emit_b` surplus child's buffers go back into the
    /// job for the master thread to return to the slab.
    fn breed_and_eval(
        &self,
        parents: &[Solution],
        job: &mut PairJob,
        rates: MutationRates,
        ctx: &EvalCtx<'_, '_>,
        scratch: &mut EvalScratch,
    ) -> (Solution, Option<Solution>) {
        let mut rng = Rng::seed_from_u64(job.pair_seed);
        let mut a = std::mem::take(&mut job.out_a);
        let mut b = std::mem::take(&mut job.out_b);
        breed_pair_into(
            &parents[job.a].genome,
            &parents[job.b].genome,
            rates,
            &mut rng,
            &mut scratch.upmx,
            &mut a,
            &mut b,
        );
        self.enforce_ablation_switches(&mut a);
        self.enforce_ablation_switches(&mut b);
        let obj_a = std::mem::take(&mut job.obj_a);
        let sol_a = self.eval_one(a, obj_a, job.seed_a, true, job.measure, ctx, scratch);
        let sol_b = if job.emit_b {
            let obj_b = std::mem::take(&mut job.obj_b);
            Some(self.eval_one(b, obj_b, job.seed_b, true, job.measure, ctx, scratch))
        } else {
            job.out_b = b;
            None
        };
        (sol_a, sol_b)
    }

    /// The shared fan-out scaffold behind [`Self::evaluate_batch`] and
    /// [`Self::evaluate_offspring`]: chunk `jobs` contiguously across
    /// `config.threads` scoped threads (0 = available cores), run `per_job`
    /// with a per-worker [`EvalScratch`], and gather results **by index** —
    /// never by completion order — so output is independent of scheduling.
    ///
    /// `scratches` is the caller-owned per-worker scratch pool (worker `i`
    /// always takes `scratches[i]`, grown on demand): warmed arenas survive
    /// across generations instead of being rebuilt cold per fan-out. Reuse
    /// cannot affect results — every scratch buffer is cleared or
    /// overwritten before it is read.
    fn fan_out<J: Send, R: Send>(
        &self,
        jobs: &mut [J],
        scratches: &mut Vec<EvalScratch>,
        per_job: &(impl Fn(&mut J, &mut EvalScratch) -> R + Sync),
    ) -> Vec<R> {
        // Re-resolved per fan-out (i.e. per generation phase): with a
        // shared core budget the width tracks what is free *right now* —
        // the lease is held for this fan-out only and returned at the end
        // of the call, so cores freed by finished sibling jobs are
        // reclaimed at the next generation. The lease alone bounds the
        // width (no re-clamp against `config.threads`).
        let (threads, _lease) = crate::util::threads::leased_threads(
            self.config.core_budget.as_ref(),
            self.config.threads,
            jobs.len(),
        );
        if scratches.len() < threads {
            scratches.resize_with(threads, EvalScratch::default);
        }
        let mut out: Vec<Option<R>> = Vec::with_capacity(jobs.len());
        out.resize_with(jobs.len(), || None);
        let run_chunk =
            |job_chunk: &mut [J], out_chunk: &mut [Option<R>], scratch: &mut EvalScratch| {
                for (slot, job) in out_chunk.iter_mut().zip(job_chunk) {
                    *slot = Some(per_job(job, scratch));
                }
            };
        if threads <= 1 {
            run_chunk(jobs, &mut out, &mut scratches[0]);
        } else {
            let chunk = jobs.len().div_ceil(threads);
            let run_chunk = &run_chunk;
            std::thread::scope(|scope| {
                for ((job_chunk, out_chunk), scratch) in jobs
                    .chunks_mut(chunk)
                    .zip(out.chunks_mut(chunk))
                    .zip(scratches.iter_mut())
                {
                    scope.spawn(move || run_chunk(job_chunk, out_chunk, scratch));
                }
            });
        }
        out.into_iter().map(|s| s.expect("every job processed")).collect()
    }

    /// Batch evaluation: score a whole job list through [`Self::fan_out`].
    fn evaluate_batch(
        &self,
        mut jobs: Vec<EvalJob>,
        scratches: &mut Vec<EvalScratch>,
        ctx: &EvalCtx<'_, '_>,
    ) -> Vec<Solution> {
        self.fan_out(&mut jobs, scratches, &|job, scratch| {
            let genome = std::mem::take(&mut job.genome);
            let obj = std::mem::take(&mut job.obj);
            let (seed, ls, measure) = (job.seed, job.local_search, job.measure);
            self.eval_one(genome, obj, seed, ls, measure, ctx, scratch)
        })
    }

    /// Offspring fan-out: breed + evaluate every pair job across the worker
    /// threads, flattening the per-pair results back in pair order (child a,
    /// then child b) — the same offspring order the master-thread loop
    /// produced before this moved into the fan-out.
    fn evaluate_offspring(
        &self,
        parents: &[Solution],
        pairs: &mut [PairJob],
        scratches: &mut Vec<EvalScratch>,
        ctx: &EvalCtx<'_, '_>,
    ) -> Vec<Solution> {
        let rates = MutationRates {
            cut: self.config.p_mutate_cut,
            map: self.config.p_mutate_map,
            prio: self.config.p_mutate_prio,
        };
        let results = self.fan_out(pairs, scratches, &|job, scratch| {
            self.breed_and_eval(parents, job, rates, ctx, scratch)
        });
        let mut children = Vec::with_capacity(results.len() * 2);
        for (a, b) in results {
            children.push(a);
            children.extend(b);
        }
        children
    }

    /// Deprecated silent run. Prefer [`crate::api::AnalysisSession::run`]
    /// (or `run_observed` for streamed per-generation progress).
    #[deprecated(
        since = "0.2.0",
        note = "use puzzle::api::AnalysisSession::run / run_observed"
    )]
    pub fn run(&self) -> AnalysisResult {
        self.run_observed(&mut crate::api::null_observer())
    }

    /// Run the full GA search with a run-local profiler, streaming
    /// per-generation progress through the observer.
    pub(crate) fn run_observed(&self, observer: &mut dyn crate::api::Observer) -> AnalysisResult {
        let pm_probe: &dyn crate::profiler::DeviceProbe = self.perf;
        let profiler = Profiler::new(pm_probe);
        self.run_observed_with(&profiler, observer)
    }

    /// Run the full GA search against a caller-owned profiler (the session
    /// layer shares one across analyze → deploy so deployment reuses the
    /// best-config memo), streaming per-generation progress through the
    /// observer (generation 0 is the evaluated initial population). Any
    /// observer hook returning `Break` cancels the search: the result
    /// carries the front of the population evaluated so far, with
    /// `cancelled` set.
    pub(crate) fn run_observed_with(
        &self,
        profiler: &Profiler<'_>,
        observer: &mut dyn crate::api::Observer,
    ) -> AnalysisResult {
        let mut rng = Rng::seed_from_u64(self.config.seed);
        let nets = &self.scenario.networks;
        let plan_cache = DecodedPlanCache::new();
        let groups = self.groups();
        let evals = AtomicUsize::new(0);
        let ctx = EvalCtx {
            profiler,
            cache: &plan_cache,
            groups: &groups,
            evals: &evals,
        };

        // Initial population: random genomes plus structured seeds — all-NPU
        // / all-GPU / all-CPU, the per-model-fastest mapping, and the
        // Best-Mapping Pareto mappings. The paper notes Puzzle "also
        // explored these [whole-model mapping] solutions" (§6.4); seeding
        // them makes that subsumption explicit instead of hoping the random
        // init rediscovers 3^N points.
        let mut population: Vec<Genome> = Vec::with_capacity(self.config.population);
        population.push(Genome::all_on(nets, Processor::Npu));
        population.push(Genome::all_on(nets, Processor::Gpu));
        population.push(Genome::all_on(nets, Processor::Cpu));
        population.push(self.best_mapping_seed());
        for sol in crate::baselines::best_mapping(self.scenario, self.perf, self.config.sim_requests)
        {
            if population.len() >= self.config.population / 2 {
                break;
            }
            population.push(sol.genome);
        }
        while population.len() < self.config.population {
            population.push(Genome::random(nets, self.config.cut_prob_init, &mut rng));
        }
        for g in &mut population {
            self.enforce_ablation_switches(g);
        }

        // Initial population: batch-evaluated, no local search / measurement
        // (as in the seed). Seeds are drawn for every job regardless so the
        // master stream advances identically whatever the flags.
        let init_jobs: Vec<EvalJob> = population
            .into_iter()
            .map(|g| EvalJob {
                seed: rng.next_u64(),
                genome: g,
                obj: Vec::new(),
                local_search: false,
                measure: false,
            })
            .collect();
        // Per-worker evaluation scratches, persisted across every fan-out
        // of this run so warmed arenas are never rebuilt cold.
        let mut scratches: Vec<EvalScratch> = Vec::new();
        let mut evaluated: Vec<Solution> = self.evaluate_batch(init_jobs, &mut scratches, &ctx);

        // Master-thread per-generation scratch, reused across generations:
        // the ENS selection workspace, the flattened objective matrix, the
        // survivor index list, the shuffle order, the pair-job list, and
        // the parent+children pool. Steady-state replacement allocates
        // nothing beyond the pooled Solution moves.
        let mut selection = SelectionWorkspace::new();
        let mut flat_objs: Vec<f64> = Vec::new();
        let mut keep: Vec<usize> = Vec::new();
        let mut order: Vec<usize> = Vec::new();
        let mut pairs: Vec<PairJob> = Vec::new();
        let mut pool: Vec<Solution> = Vec::new();
        // Free list of (genome, objectives) buffers harvested from the
        // solutions NSGA-III replacement drops. Pair jobs pop their child
        // buffers from here, so once the search is warm a generation's
        // reproduction recycles the previous generation's casualties
        // instead of allocating fresh genome/objective storage
        // (ROADMAP: generation-zero-alloc).
        let mut slab: Vec<(Genome, Vec<f64>)> = Vec::new();

        let avg_score = |sols: &[Solution]| -> f64 {
            sols.iter()
                .map(|s| s.objectives.iter().sum::<f64>())
                .sum::<f64>()
                / sols.len().max(1) as f64
        };

        let mut best_avg = avg_score(&evaluated);
        let mut stale = 0usize;
        let mut generations_run = 0usize;
        let mut cancelled = emit_batch(observer, 0, evaluated.len(), &ctx).is_break();
        cancelled |= emit_progress(observer, 0, &evaluated, best_avg, stale, &ctx).is_break();

        for _gen in 0..self.config.max_generations {
            if cancelled {
                break;
            }
            generations_run += 1;
            // All parents reproduce: shuffle and pair. The breeding itself
            // (clone + crossover + mutation) happens inside the fan-out; the
            // master thread only draws the shuffle and the per-pair /
            // per-child seeds, sequentially, so results are independent of
            // the thread count.
            order.clear();
            order.extend(0..evaluated.len());
            for i in (1..order.len()).rev() {
                let j = rng.gen_range_inclusive(0, i);
                order.swap(i, j);
            }
            let measure = self.config.measure_reps > 0;
            let mut remaining = evaluated.len();
            pairs.clear();
            for pair in order.chunks(2) {
                if remaining == 0 {
                    break;
                }
                // An odd population's last pair emits only its first child
                // (the pre-fan-out loop truncated the surplus offspring).
                let emit_b = remaining >= 2;
                let pair_seed = rng.next_u64();
                let seed_a = rng.next_u64();
                let seed_b = if emit_b { rng.next_u64() } else { 0 };
                // Child buffers come off the free-list slab (empty defaults
                // until replacement has fed it).
                let (out_a, obj_a) = slab.pop().unwrap_or_default();
                let (out_b, obj_b) = slab.pop().unwrap_or_default();
                pairs.push(PairJob {
                    a: pair[0],
                    b: pair[pair.len() - 1],
                    pair_seed,
                    seed_a,
                    seed_b,
                    emit_b,
                    measure,
                    out_a,
                    out_b,
                    obj_a,
                    obj_b,
                });
                remaining -= if emit_b { 2 } else { 1 };
            }
            // Breed + evaluate in one fan-out: per-pair derived seeds drive
            // crossover/mutation, per-child seeds drive probabilistic local
            // search (simulator-scored, kept only on all-objective
            // improvement) and the measurement tier (brief noisy execution)
            // before replacement.
            let children = self.evaluate_offspring(&evaluated, &mut pairs, &mut scratches, &ctx);
            // Harvest the buffers an odd population's last pair bred for
            // its surplus child but never emitted.
            for job in &mut pairs {
                if !job.emit_b {
                    slab.push((std::mem::take(&mut job.out_b), std::mem::take(&mut job.obj_b)));
                }
            }
            // Mid-generation (post-batch, pre-replacement) progress: the
            // cancellation point for long searches. A Break still performs
            // this generation's replacement so the returned front reflects
            // every evaluation paid for.
            cancelled |= emit_batch(observer, generations_run, children.len(), &ctx).is_break();

            // NSGA-III replacement over parents + children through the ENS
            // workspace (bit-identical to `nsga3_select`). Survivors are
            // *moved* out of the pool, never cloned, so retention copies no
            // genomes and no plans (`tests/batch_eval.rs` asserts the
            // underlying operations — Solution moves and plan-handle clones
            // — are plan-copy-free), the selection scratch (flattened
            // objectives, ENS fronts, niching heaps, survivor indices) lives
            // in reusable buffers, and the dropped solutions' genome and
            // objectives buffers go back to the slab for the next
            // generation's pair jobs.
            std::mem::swap(&mut pool, &mut evaluated);
            pool.extend(children);
            let m = pool.first().map(|s| s.objectives.len()).unwrap_or(1);
            flat_objs.clear();
            for s in &pool {
                flat_objs.extend_from_slice(&s.objectives);
            }
            keep.clear();
            keep.extend_from_slice(selection.select(&flat_objs, m, self.config.population));
            keep.sort_unstable();
            keep.dedup();
            take_by_index_into(&mut pool, &keep, &mut evaluated, &mut slab);

            // Convergence check on the average aggregate.
            let avg = avg_score(&evaluated);
            if avg < best_avg * 0.999 {
                best_avg = avg;
                stale = 0;
            } else {
                stale += 1;
            }
            cancelled |=
                emit_progress(observer, generations_run, &evaluated, avg, stale, &ctx).is_break();
            if cancelled || stale >= self.config.patience {
                break;
            }
        }

        // Final Pareto front (moved, not cloned).
        let objs: Vec<Vec<f64>> = evaluated.iter().map(|s| s.objectives.clone()).collect();
        let fronts = fast_non_dominated_sort(&objs);
        let mut front = fronts.first().cloned().unwrap_or_default();
        front.sort_unstable();
        front.dedup();
        let pareto = take_by_index(evaluated, &front);
        let (hits, misses) = profiler.stats();
        let (plan_hits, plan_misses) = plan_cache.stats();
        AnalysisResult {
            pareto,
            generations_run,
            evaluations: evals.load(Ordering::Relaxed),
            profile_cache_hits: hits,
            profile_measurements: misses,
            plan_cache_hits: plan_hits,
            plan_cache_misses: plan_misses,
            cancelled,
        }
    }

    /// Apply the chromosome-ablation switches to a genome in place.
    fn enforce_ablation_switches(&self, g: &mut Genome) {
        if !self.config.explore_partition {
            for genes in &mut g.networks {
                genes.cuts.iter_mut().for_each(|c| *c = false);
            }
        }
        if !self.config.explore_priority {
            g.priority = (0..g.priority.len()).collect();
        }
    }

    /// Seed genome: each network whole, on its individually fastest
    /// processor (a "best mapping"-like starting point).
    fn best_mapping_seed(&self) -> Genome {
        let nets = &self.scenario.networks;
        let mut genome = Genome::all_on(nets, Processor::Npu);
        for (i, net) in nets.iter().enumerate() {
            let all: Vec<crate::graph::LayerId> =
                (0..net.num_layers()).map(crate::graph::LayerId).collect();
            let best = Processor::ALL
                .into_iter()
                .min_by(|&a, &b| {
                    let ta = self.perf.best_config_for(net, &all, a).1;
                    let tb = self.perf.best_config_for(net, &all, b).1;
                    ta.partial_cmp(&tb).unwrap()
                })
                .unwrap();
            genome.networks[i] = crate::ga::NetworkGenes::whole_on(net, best);
        }
        genome
    }
}

/// Move the solutions at `indices` (strictly increasing, deduplicated) out
/// of `pool`, dropping the rest. No `Solution` is ever cloned — with
/// `Arc<PlanSet>` plan sharing this keeps survivor retention free of plan
/// copies.
fn take_by_index(pool: Vec<Solution>, indices: &[usize]) -> Vec<Solution> {
    let mut out = Vec::with_capacity(indices.len());
    let mut next = indices.iter().copied().peekable();
    for (i, sol) in pool.into_iter().enumerate() {
        if next.peek() == Some(&i) {
            next.next();
            out.push(sol);
        }
    }
    out
}

/// [`take_by_index`] with full buffer recycling: survivors are drained from
/// `pool` into `out` (cleared first; both keep their capacity), and every
/// dropped solution's genome and objectives buffers are pushed onto the
/// `slab` free list for the next generation's pair jobs to reuse. The
/// dropped solution's plan handle (`Arc<PlanSet>`) is simply released — the
/// decode memo keeps plans alive, so nothing is deep-freed here either.
fn take_by_index_into(
    pool: &mut Vec<Solution>,
    indices: &[usize],
    out: &mut Vec<Solution>,
    slab: &mut Vec<(Genome, Vec<f64>)>,
) {
    out.clear();
    let mut next = indices.iter().copied().peekable();
    for (i, sol) in pool.drain(..).enumerate() {
        if next.peek() == Some(&i) {
            next.next();
            out.push(sol);
        } else {
            let Solution { genome, objectives, plan_set } = sol;
            drop(plan_set);
            slab.push((genome, objectives));
        }
    }
}

/// Send one [`crate::api::BatchProgress`] snapshot (after a batch of
/// candidate evaluations; mid-generation granularity).
fn emit_batch(
    observer: &mut dyn crate::api::Observer,
    generation: usize,
    batch_size: usize,
    ctx: &EvalCtx<'_, '_>,
) -> std::ops::ControlFlow<()> {
    observer.on_batch(&crate::api::BatchProgress {
        generation,
        batch_size,
        evaluations: ctx.evals.load(Ordering::Relaxed),
    })
}

/// Build and send one [`crate::api::GenerationProgress`] snapshot.
#[allow(clippy::too_many_arguments)]
fn emit_progress(
    observer: &mut dyn crate::api::Observer,
    generation: usize,
    evaluated: &[Solution],
    avg_aggregate: f64,
    stale_generations: usize,
    ctx: &EvalCtx<'_, '_>,
) -> std::ops::ControlFlow<()> {
    let best = evaluated
        .iter()
        .min_by(|a, b| a.max_objective().partial_cmp(&b.max_objective()).unwrap());
    let (profile_cache_hits, profile_measurements) = ctx.profiler.stats();
    let (plan_cache_hits, plan_cache_misses) = ctx.cache.stats();
    let (probe_skips, best_memo_hits) = ctx.profiler.probe_stats();
    let progress = crate::api::GenerationProgress {
        generation,
        evaluations: ctx.evals.load(Ordering::Relaxed),
        best_objectives: best.map(|s| s.objectives.as_slice()).unwrap_or(&[]),
        avg_aggregate,
        stale_generations,
        profile_cache_hits,
        profile_measurements,
        plan_cache_hits,
        plan_cache_misses,
        probe_skips,
        best_memo_hits,
    };
    observer.on_generation(&progress)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn tiny_scenario() -> Scenario {
        Scenario::from_groups("tiny", &[vec![0, 1, 6]])
    }

    /// In-crate shorthand for the engine path (external callers go through
    /// `puzzle::api`).
    fn run(s: &Scenario, pm: &PerfModel, config: GaConfig) -> AnalysisResult {
        StaticAnalyzer::engine(s, pm, config).run_observed(&mut crate::api::null_observer())
    }

    #[test]
    fn analyzer_produces_pareto_front() {
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let result = run(&s, &pm, GaConfig::quick(1));
        assert!(!result.pareto.is_empty());
        assert!(result.evaluations > 16);
        // Pareto front is mutually non-dominated.
        for a in &result.pareto {
            for b in &result.pareto {
                assert_ne!(
                    crate::ga::fast_non_dominated_sort(&[a.objectives.clone(), b.objectives.clone()]).len() == 2
                        && a.objectives.iter().zip(&b.objectives).all(|(x, y)| x <= y)
                        && a.objectives != b.objectives,
                    true,
                    "dominated pair kept in pareto set"
                );
            }
        }
    }

    #[test]
    fn analyzer_beats_or_matches_all_cpu_seed() {
        // The search must at least rediscover something no worse than
        // running everything on the CPU.
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let analyzer = StaticAnalyzer::engine(&s, &pm, GaConfig::quick(2));
        let result = analyzer.run_observed(&mut crate::api::null_observer());
        let profiler = Profiler::new(&pm);
        let groups = analyzer.groups();
        let cpu = Genome::all_on(&s.networks, Processor::Cpu);
        let (cpu_objs, _) = analyzer.evaluate(&cpu, &profiler, &groups);
        let best = result.best_by_max_makespan();
        assert!(
            best.objectives[0] <= cpu_objs[0] * 1.05,
            "GA ({:?}) worse than all-CPU ({:?})",
            best.objectives, cpu_objs
        );
    }

    #[test]
    fn cache_reuse_is_substantial() {
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let result = run(&s, &pm, GaConfig::quick(3));
        assert!(
            result.profile_cache_hits > result.profile_measurements,
            "merkle cache ineffective: {} hits vs {} measures",
            result.profile_cache_hits, result.profile_measurements
        );
    }

    #[test]
    fn take_by_index_recycling_matches_and_recycles() {
        let mk = |i: usize| Solution {
            genome: Genome { networks: Vec::new(), priority: vec![i] },
            objectives: vec![i as f64],
            plan_set: Arc::new(PlanSet { plans: Vec::new(), compiled: Vec::new() }),
        };
        let expect = take_by_index((0..6).map(mk).collect(), &[1, 3, 4]);
        let mut pool: Vec<Solution> = (0..6).map(mk).collect();
        let mut out = Vec::new();
        let mut slab: Vec<(Genome, Vec<f64>)> = Vec::new();
        take_by_index_into(&mut pool, &[1, 3, 4], &mut out, &mut slab);
        assert!(pool.is_empty(), "pool must be drained");
        assert_eq!(
            out.iter().map(|s| s.objectives[0]).collect::<Vec<_>>(),
            expect.iter().map(|s| s.objectives[0]).collect::<Vec<_>>(),
            "survivors must match take_by_index"
        );
        // Dropped solutions' genome + objectives buffers land on the free
        // list, in pool order.
        let dropped: Vec<usize> = slab.iter().map(|(g, _)| g.priority[0]).collect();
        assert_eq!(dropped, vec![0, 2, 5]);
        assert_eq!(slab.iter().map(|(_, o)| o[0]).collect::<Vec<_>>(), vec![0.0, 2.0, 5.0]);
    }

    #[test]
    fn recycled_breed_and_eval_is_allocation_free() {
        // The steady-state reproduction path: once the decode memo holds the
        // children and every scratch/recycled buffer is warm, breeding and
        // scoring a pair job must not touch the allocator at all. Run the
        // exact same pair job twice — same parents and seeds mean the second
        // run's children are decode-memo hits — feeding the second job the
        // first run's solution buffers, exactly as the slab does between
        // generations.
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let analyzer = StaticAnalyzer::engine(&s, &pm, GaConfig::quick(5));
        let profiler = Profiler::new(&pm);
        let plan_cache = DecodedPlanCache::new();
        let groups = analyzer.groups();
        let evals = AtomicUsize::new(0);
        let ctx = EvalCtx {
            profiler: &profiler,
            cache: &plan_cache,
            groups: &groups,
            evals: &evals,
        };
        let mut scratch = EvalScratch::default();
        let mut rng = Rng::seed_from_u64(77);
        let parents: Vec<Solution> = (0..2)
            .map(|i| {
                let g = Genome::random(&s.networks, 0.3, &mut rng);
                analyzer.eval_one(g, Vec::new(), 100 + i, false, false, &ctx, &mut scratch)
            })
            .collect();
        let rates = MutationRates {
            cut: analyzer.config.p_mutate_cut,
            map: analyzer.config.p_mutate_map,
            prio: analyzer.config.p_mutate_prio,
        };
        let job = |out_a: Genome, out_b: Genome, obj_a: Vec<f64>, obj_b: Vec<f64>| PairJob {
            a: 0,
            b: 1,
            pair_seed: 41,
            seed_a: 42,
            seed_b: 43,
            emit_b: true,
            measure: true,
            out_a,
            out_b,
            obj_a,
            obj_b,
        };
        let mut cold = job(Genome::default(), Genome::default(), Vec::new(), Vec::new());
        let (warm_a, warm_b) =
            analyzer.breed_and_eval(&parents, &mut cold, rates, &ctx, &mut scratch);
        let warm_b = warm_b.expect("emit_b");
        // Second run: recycled buffers, warm caches, same seeds.
        let mut recycled = job(
            warm_a.genome,
            warm_b.genome,
            warm_a.objectives.clone(),
            warm_b.objectives.clone(),
        );
        let before = crate::util::alloc::thread_allocations();
        let (sol_a, sol_b) =
            analyzer.breed_and_eval(&parents, &mut recycled, rates, &ctx, &mut scratch);
        let allocs = crate::util::alloc::thread_allocations() - before;
        assert_eq!(allocs, 0, "warm recycled breed+eval must not allocate");
        // And recycling changes nothing about the result.
        assert_eq!(sol_a.objectives, warm_a.objectives);
        assert_eq!(sol_b.expect("emit_b").objectives, warm_b.objectives);
    }

    #[test]
    fn deterministic_for_seed() {
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let r1 = run(&s, &pm, GaConfig::quick(7));
        let r2 = run(&s, &pm, GaConfig::quick(7));
        let o1: Vec<&Vec<f64>> = r1.pareto.iter().map(|s| &s.objectives).collect();
        let o2: Vec<&Vec<f64>> = r2.pareto.iter().map(|s| &s.objectives).collect();
        assert_eq!(o1, o2);
    }
}
