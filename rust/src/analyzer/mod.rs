//! The Static Analyzer (paper §4, Fig 4 & 8): Optimizer ↔ Simulator ↔
//! Runtime-Evaluator loop.
//!
//! Per generation: all parents reproduce (no elite selection), one-point /
//! UPMX crossover, mutation, probabilistic local search (simulator-scored,
//! accepted only on all-objective improvement), then candidate evaluation
//! and NSGA-III replacement. The stop rule is 3 generations without average
//! improvement, as in the paper.
//!
//! Two evaluation tiers mirror the paper:
//! * **simulation-based** — the fast discrete-event simulator, used inside
//!   local search and for the population objectives;
//! * **measurement-based** — "brief execution on the target device" before
//!   Pareto updates: a noisy re-evaluation (the calibrated noise model, or
//!   the real runtime in hardware mode) that demotes candidates whose
//!   simulated promise does not survive device fluctuation (the paper's
//!   Scenario-6 observation).
//!
//! ## Batch evaluation engine (§Perf, this PR)
//!
//! Candidate scoring — the search's entire cost — runs through a **batch
//! evaluator**: each generation's offspring become [`EvalJob`]s (genome +
//! a per-job RNG seed derived *sequentially* from the master stream), which
//! a `std::thread::scope` fan-out scores in parallel. Each worker thread
//! owns one reusable [`SimWorkspace`] (zero steady-state allocation) and
//! shares the [`DecodedPlanCache`] genome→plan memo and the merkle-keyed
//! profile DB. Because every job's outcome depends only on its genome and
//! its derived seed — never on cross-thread state — results gathered back
//! by index are **bit-identical for any thread count**, including
//! `threads = 1` (tested by `deterministic_across_thread_counts`). Only the
//! profiler/memo hit-miss *counters* may vary under concurrency (two
//! threads can race the same miss); objectives, Pareto fronts, and
//! evaluation counts never do.
//!
//! ## Entry points (§API, this PR)
//!
//! External callers drive the analyzer through the owned session layer in
//! [`crate::api`]: a [`crate::api::SessionBuilder`] yields an
//! `AnalysisSession` whose `run`/`run_observed` stream per-generation
//! progress and return an `Analysis` that deploys straight to a
//! [`crate::coordinator::Coordinator`]. The borrow-based
//! [`StaticAnalyzer::new`]/[`StaticAnalyzer::run`] remain as deprecated
//! shims. Solutions share their decoded plans via [`Arc<PlanSet>`] — Pareto
//! bookkeeping moves candidates instead of deep-cloning their
//! `Vec<ExecutionPlan>`.

pub mod solution_io;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::util::rng::Rng;

use crate::comm::CommModel;
use crate::ga::{
    decode, fast_non_dominated_sort, merge_neighbors, mutate, nsga3_select, one_point_crossover,
    reposition_adjacent, DecodedPlanCache, Genome, PlanSet,
};

use crate::perf::PerfModel;
use crate::profiler::Profiler;
use crate::scenario::Scenario;
use crate::sim::{simulate, ExecutionPlan, GroupSpec, SimOptions, SimWorkspace};
use crate::Processor;

/// Analyzer hyper-parameters.
#[derive(Debug, Clone)]
pub struct GaConfig {
    pub population: usize,
    pub max_generations: usize,
    /// Stop after this many generations without average improvement
    /// (paper: 3).
    pub patience: usize,
    pub cut_prob_init: f64,
    pub p_mutate_cut: f64,
    pub p_mutate_map: f64,
    pub p_mutate_prio: f64,
    /// Probability of attempting local search on a fresh child.
    pub p_local_search: f64,
    /// Requests per group when simulating a candidate.
    pub sim_requests: usize,
    pub seed: u64,
    /// Number of noisy "brief execution" repetitions in the measurement
    /// tier (0 disables the tier).
    pub measure_reps: usize,
    /// Explore the partition chromosome (ablation switch: off freezes all
    /// networks whole, reducing the search to mapping+priority — the Kang
    /// et al. / Best-Mapping regime the paper compares against).
    pub explore_partition: bool,
    /// Explore the priority chromosome (off pins the identity order).
    pub explore_priority: bool,
    /// Evaluator threads for batch candidate scoring. `0` = one per
    /// available core. Results are identical for every value (the
    /// determinism contract above); `1` forces the serial path.
    pub threads: usize,
}

impl Default for GaConfig {
    fn default() -> Self {
        GaConfig {
            population: 48,
            max_generations: 40,
            patience: 3,
            cut_prob_init: 0.15,
            p_mutate_cut: 0.03,
            p_mutate_map: 0.06,
            p_mutate_prio: 0.30,
            p_local_search: 0.35,
            sim_requests: 20,
            seed: 23,
            measure_reps: 3,
            explore_partition: true,
            explore_priority: true,
            threads: 0,
        }
    }
}

impl GaConfig {
    /// A reduced-budget config for tests and examples.
    pub fn quick(seed: u64) -> GaConfig {
        GaConfig {
            population: 24,
            max_generations: 14,
            sim_requests: 10,
            measure_reps: 2,
            seed,
            ..Default::default()
        }
    }
}

/// One evaluated candidate.
///
/// The decoded plans are held as a shared [`Arc<PlanSet>`] (one decode per
/// genome, owned by the [`DecodedPlanCache`]): cloning a `Solution` — Pareto
/// archive updates, survivor carry-over, deployment hand-off — never copies
/// the underlying `Vec<ExecutionPlan>` (the per-candidate deep clone this
/// replaced was the analyzer's dominant steady-state allocation).
#[derive(Debug, Clone)]
pub struct Solution {
    pub genome: Genome,
    /// Minimized objectives: `[avg makespan, p90 makespan]` per group,
    /// flattened (paper: "average and 90th percentile of makespans for each
    /// model group").
    pub objectives: Vec<f64>,
    /// Decoded plans + one-time structural compilation, shared across every
    /// clone of this solution (and with the decode memo).
    pub plan_set: Arc<PlanSet>,
}

impl Solution {
    /// The executable per-network plans of this solution.
    pub fn plans(&self) -> &[ExecutionPlan] {
        &self.plan_set.plans
    }

    /// Worst (maximum) objective — the paper's single-number selection
    /// metric ("the smallest maximum makespan", §5.3).
    pub fn max_objective(&self) -> f64 {
        self.objectives.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
    }
}

/// Analyzer output: the Pareto archive and search telemetry.
#[derive(Debug, Clone)]
pub struct AnalysisResult {
    pub pareto: Vec<Solution>,
    pub generations_run: usize,
    pub evaluations: usize,
    pub profile_cache_hits: u64,
    pub profile_measurements: u64,
    /// Genome→plan memo hits (decodes skipped entirely).
    pub plan_cache_hits: u64,
    /// Actual decode + compile executions.
    pub plan_cache_misses: u64,
    /// True when an [`crate::api::Observer`] hook returned
    /// [`std::ops::ControlFlow::Break`]: the Pareto front reflects the
    /// population at the moment of cancellation, not convergence.
    pub cancelled: bool,
}

impl AnalysisResult {
    /// The solution minimizing the maximum (worst-group) average makespan —
    /// the paper's selection rule for single-number comparisons ("choosing
    /// the solution with the smallest maximum makespan", §5.3).
    pub fn best_by_max_makespan(&self) -> &Solution {
        self.pareto
            .iter()
            .min_by(|a, b| a.max_objective().partial_cmp(&b.max_objective()).unwrap())
            .expect("non-empty pareto set")
    }
}

/// One unit of batch-evaluation work: a candidate genome plus the RNG seed
/// that drives its local-search decisions and measurement-tier noise. Seeds
/// are drawn sequentially from the master stream *before* the parallel
/// fan-out, which is what makes results thread-count independent.
struct EvalJob {
    genome: Genome,
    seed: u64,
    local_search: bool,
    measure: bool,
}

/// Shared, thread-safe evaluation context: the profile DB, the genome→plan
/// memo, the group specs, and the evaluation counter. Everything here is
/// value-deterministic under concurrent access (see module docs).
struct EvalCtx<'a, 'd> {
    profiler: &'a Profiler<'d>,
    cache: &'a DecodedPlanCache,
    groups: &'a [GroupSpec],
    evals: &'a AtomicUsize,
}

/// The Static Analyzer.
pub struct StaticAnalyzer<'a> {
    pub scenario: &'a Scenario,
    pub perf: &'a PerfModel,
    pub comm: CommModel,
    pub config: GaConfig,
    /// Period per group at the search multiplier (paper searches at α = 1).
    pub periods: Vec<f64>,
}

impl<'a> StaticAnalyzer<'a> {
    /// Internal constructor: the engine behind [`crate::api::AnalysisSession`]
    /// (which owns the scenario/perf data this borrows for the duration of a
    /// run).
    pub(crate) fn engine(scenario: &'a Scenario, perf: &'a PerfModel, config: GaConfig) -> Self {
        let periods = scenario.periods(1.0, perf);
        StaticAnalyzer {
            scenario,
            perf,
            comm: CommModel::paper_calibrated(),
            config,
            periods,
        }
    }

    /// Deprecated borrow-based entry point. Prefer
    /// [`crate::api::SessionBuilder`], which owns its inputs and exposes the
    /// whole analyze → deploy flow.
    #[deprecated(
        since = "0.2.0",
        note = "use puzzle::api::SessionBuilder to construct an AnalysisSession"
    )]
    pub fn new(scenario: &'a Scenario, perf: &'a PerfModel, config: GaConfig) -> Self {
        Self::engine(scenario, perf, config)
    }

    /// Group specs at the search-time periods.
    pub fn groups(&self) -> Vec<GroupSpec> {
        self.scenario
            .groups
            .iter()
            .zip(&self.periods)
            .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
            .collect()
    }

    /// Simulate one genome → flattened `[avg, p90]` objectives per group.
    /// Serial convenience path (tests, one-off scoring); the search itself
    /// goes through [`Self::run`]'s batch evaluator.
    pub fn evaluate(
        &self,
        genome: &Genome,
        profiler: &Profiler<'_>,
        groups: &[GroupSpec],
    ) -> (Vec<f64>, Vec<ExecutionPlan>) {
        let plans = decode(&self.scenario.networks, genome, profiler, &self.comm);
        let opts = self.sim_opts();
        let result = simulate(&plans, groups, &self.comm, &opts);
        let mut objectives = Vec::with_capacity(groups.len() * 2);
        for g in 0..groups.len() {
            objectives.push(result.avg_makespan(g));
            objectives.push(result.p90_makespan(g));
        }
        (objectives, plans)
    }

    fn sim_opts(&self) -> SimOptions {
        SimOptions { requests_per_group: self.config.sim_requests, ..Default::default() }
    }

    /// Memoized evaluation through the shared plan cache and a reusable
    /// per-thread workspace: decode (or memo-hit), simulate allocation-free,
    /// read objectives out of the workspace.
    fn evaluate_cached(
        &self,
        genome: &Genome,
        ctx: &EvalCtx<'_, '_>,
        ws: &mut SimWorkspace,
    ) -> (Vec<f64>, Arc<PlanSet>) {
        let set = ctx.cache.decode(&self.scenario.networks, genome, ctx.profiler, &self.comm);
        let opts = self.sim_opts();
        ws.run(&set.plans, &set.compiled, ctx.groups, &self.comm, &opts);
        let mut objectives = Vec::with_capacity(ctx.groups.len() * 2);
        ws.objectives_into(&mut objectives);
        ctx.evals.fetch_add(1, Ordering::Relaxed);
        (objectives, set)
    }

    /// Measurement tier: re-evaluate with execution-time noise, and score by
    /// the worst observed repetition. Candidates that only look good in the
    /// noiseless simulation get demoted here. Durations are perturbed in a
    /// reusable scratch plan set; the structural compilation is shared with
    /// the noiseless plans (noise never changes dependencies).
    fn measure_with(
        &self,
        set: &PlanSet,
        ctx: &EvalCtx<'_, '_>,
        rng: &mut Rng,
        ws: &mut SimWorkspace,
        scratch: &mut Vec<ExecutionPlan>,
    ) -> Vec<f64> {
        let opts = self.sim_opts();
        let mut worst: Vec<f64> = vec![0.0; ctx.groups.len() * 2];
        scratch.clear();
        scratch.extend(set.plans.iter().cloned());
        for _ in 0..self.config.measure_reps.max(1) {
            for (noisy, nominal) in scratch.iter_mut().zip(&set.plans) {
                for (nt, t) in noisy.tasks.iter_mut().zip(&nominal.tasks) {
                    nt.duration = self.perf.sample(t.duration, t.processor, rng);
                }
            }
            ws.run(scratch, &set.compiled, ctx.groups, &self.comm, &opts);
            for g in 0..ctx.groups.len() {
                worst[g * 2] = worst[g * 2].max(ws.avg_makespan(g));
                worst[g * 2 + 1] = worst[g * 2 + 1].max(ws.p90_makespan(g));
            }
        }
        worst
    }

    /// Score one job end-to-end: memoized evaluation, seed-driven local
    /// search, measurement tier. Everything the job touches is either its
    /// own (`rng` from the derived seed, the thread-local workspace and
    /// scratch) or value-deterministic shared state (profile DB, plan memo),
    /// so the result is a pure function of (genome, seed).
    fn eval_one(
        &self,
        job: &EvalJob,
        ctx: &EvalCtx<'_, '_>,
        ws: &mut SimWorkspace,
        scratch: &mut Vec<ExecutionPlan>,
    ) -> Solution {
        let (objectives, set) = self.evaluate_cached(&job.genome, ctx, ws);
        let mut sol = Solution { genome: job.genome.clone(), objectives, plan_set: set };
        if job.local_search || job.measure {
            let mut rng = Rng::seed_from_u64(job.seed);
            if job.local_search && rng.gen_bool(self.config.p_local_search) {
                let nets = &self.scenario.networks;
                for _ in 0..2 {
                    let cand = if rng.gen_bool(0.5) {
                        merge_neighbors(&sol.genome, &mut rng)
                    } else {
                        reposition_adjacent(nets, &sol.genome, &mut rng)
                    };
                    if let Some(cand) = cand {
                        let (cobjs, cset) = self.evaluate_cached(&cand, ctx, ws);
                        let better_all = cobjs
                            .iter()
                            .zip(&sol.objectives)
                            .all(|(c, o)| c <= o)
                            && cobjs.iter().zip(&sol.objectives).any(|(c, o)| c < o);
                        if better_all {
                            sol = Solution { genome: cand, objectives: cobjs, plan_set: cset };
                        }
                    }
                }
            }
            if job.measure {
                let measured = self.measure_with(&sol.plan_set, ctx, &mut rng, ws, scratch);
                sol.objectives = measured;
            }
        }
        sol
    }

    /// Batch evaluation: score a whole job slice, fanning out across
    /// `config.threads` scoped threads (0 = available cores). Jobs are
    /// chunked contiguously and results written back by index — never by
    /// completion order — so output is independent of scheduling.
    fn evaluate_batch(&self, jobs: &[EvalJob], ctx: &EvalCtx<'_, '_>) -> Vec<Solution> {
        let threads = self.effective_threads(jobs.len());
        let mut out: Vec<Option<Solution>> = Vec::with_capacity(jobs.len());
        out.resize_with(jobs.len(), || None);
        if threads <= 1 {
            let mut ws = SimWorkspace::new();
            let mut scratch: Vec<ExecutionPlan> = Vec::new();
            for (slot, job) in out.iter_mut().zip(jobs) {
                *slot = Some(self.eval_one(job, ctx, &mut ws, &mut scratch));
            }
        } else {
            let chunk = jobs.len().div_ceil(threads);
            std::thread::scope(|scope| {
                for (job_chunk, out_chunk) in jobs.chunks(chunk).zip(out.chunks_mut(chunk)) {
                    scope.spawn(move || {
                        let mut ws = SimWorkspace::new();
                        let mut scratch: Vec<ExecutionPlan> = Vec::new();
                        for (slot, job) in out_chunk.iter_mut().zip(job_chunk) {
                            *slot = Some(self.eval_one(job, ctx, &mut ws, &mut scratch));
                        }
                    });
                }
            });
        }
        out.into_iter().map(|s| s.expect("every job evaluated")).collect()
    }

    fn effective_threads(&self, jobs: usize) -> usize {
        let configured = if self.config.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.config.threads
        };
        configured.clamp(1, jobs.max(1))
    }

    /// Deprecated silent run. Prefer [`crate::api::AnalysisSession::run`]
    /// (or `run_observed` for streamed per-generation progress).
    #[deprecated(
        since = "0.2.0",
        note = "use puzzle::api::AnalysisSession::run / run_observed"
    )]
    pub fn run(&self) -> AnalysisResult {
        self.run_observed(&mut crate::api::null_observer())
    }

    /// Run the full GA search with a run-local profiler, streaming
    /// per-generation progress through the observer.
    pub(crate) fn run_observed(&self, observer: &mut dyn crate::api::Observer) -> AnalysisResult {
        let pm_probe: &dyn crate::profiler::DeviceProbe = self.perf;
        let profiler = Profiler::new(pm_probe);
        self.run_observed_with(&profiler, observer)
    }

    /// Run the full GA search against a caller-owned profiler (the session
    /// layer shares one across analyze → deploy so deployment reuses the
    /// best-config memo), streaming per-generation progress through the
    /// observer (generation 0 is the evaluated initial population). Any
    /// observer hook returning `Break` cancels the search: the result
    /// carries the front of the population evaluated so far, with
    /// `cancelled` set.
    pub(crate) fn run_observed_with(
        &self,
        profiler: &Profiler<'_>,
        observer: &mut dyn crate::api::Observer,
    ) -> AnalysisResult {
        let mut rng = Rng::seed_from_u64(self.config.seed);
        let nets = &self.scenario.networks;
        let plan_cache = DecodedPlanCache::new();
        let groups = self.groups();
        let evals = AtomicUsize::new(0);
        let ctx = EvalCtx {
            profiler,
            cache: &plan_cache,
            groups: &groups,
            evals: &evals,
        };

        // Initial population: random genomes plus structured seeds — all-NPU
        // / all-GPU / all-CPU, the per-model-fastest mapping, and the
        // Best-Mapping Pareto mappings. The paper notes Puzzle "also
        // explored these [whole-model mapping] solutions" (§6.4); seeding
        // them makes that subsumption explicit instead of hoping the random
        // init rediscovers 3^N points.
        let mut population: Vec<Genome> = Vec::with_capacity(self.config.population);
        population.push(Genome::all_on(nets, Processor::Npu));
        population.push(Genome::all_on(nets, Processor::Gpu));
        population.push(Genome::all_on(nets, Processor::Cpu));
        population.push(self.best_mapping_seed());
        for sol in crate::baselines::best_mapping(self.scenario, self.perf, self.config.sim_requests)
        {
            if population.len() >= self.config.population / 2 {
                break;
            }
            population.push(sol.genome);
        }
        while population.len() < self.config.population {
            population.push(Genome::random(nets, self.config.cut_prob_init, &mut rng));
        }
        for g in &mut population {
            self.enforce_ablation_switches(g);
        }

        // Initial population: batch-evaluated, no local search / measurement
        // (as in the seed). Seeds are drawn for every job regardless so the
        // master stream advances identically whatever the flags.
        let init_jobs: Vec<EvalJob> = population
            .into_iter()
            .map(|g| EvalJob {
                seed: rng.next_u64(),
                genome: g,
                local_search: false,
                measure: false,
            })
            .collect();
        let mut evaluated: Vec<Solution> = self.evaluate_batch(&init_jobs, &ctx);

        let avg_score = |sols: &[Solution]| -> f64 {
            sols.iter()
                .map(|s| s.objectives.iter().sum::<f64>())
                .sum::<f64>()
                / sols.len().max(1) as f64
        };

        let mut best_avg = avg_score(&evaluated);
        let mut stale = 0usize;
        let mut generations_run = 0usize;
        let mut cancelled = emit_batch(observer, 0, evaluated.len(), &ctx).is_break();
        cancelled |= emit_progress(observer, 0, &evaluated, best_avg, stale, &ctx).is_break();

        for _gen in 0..self.config.max_generations {
            if cancelled {
                break;
            }
            generations_run += 1;
            // All parents reproduce: shuffle and pair.
            let mut order: Vec<usize> = (0..evaluated.len()).collect();
            for i in (1..order.len()).rev() {
                let j = rng.gen_range_inclusive(0, i);
                order.swap(i, j);
            }
            let mut offspring: Vec<Genome> = Vec::with_capacity(evaluated.len());
            for pair in order.chunks(2) {
                let mut a = evaluated[pair[0]].genome.clone();
                let mut b = evaluated[pair[pair.len() - 1]].genome.clone();
                one_point_crossover(&mut a, &mut b, &mut rng);
                mutate(&mut a, self.config.p_mutate_cut, self.config.p_mutate_map, self.config.p_mutate_prio, &mut rng);
                mutate(&mut b, self.config.p_mutate_cut, self.config.p_mutate_map, self.config.p_mutate_prio, &mut rng);
                self.enforce_ablation_switches(&mut a);
                self.enforce_ablation_switches(&mut b);
                offspring.push(a);
                offspring.push(b);
            }
            offspring.truncate(evaluated.len());

            // Batch-evaluate the offspring: per-child derived seeds drive
            // probabilistic local search (simulator-scored, kept only on
            // all-objective improvement) and the measurement tier (brief
            // noisy execution) before replacement.
            let jobs: Vec<EvalJob> = offspring
                .into_iter()
                .map(|g| EvalJob {
                    seed: rng.next_u64(),
                    genome: g,
                    local_search: true,
                    measure: self.config.measure_reps > 0,
                })
                .collect();
            let children = self.evaluate_batch(&jobs, &ctx);
            // Mid-generation (post-batch, pre-replacement) progress: the
            // cancellation point for long searches. A Break still performs
            // this generation's replacement so the returned front reflects
            // every evaluation paid for.
            cancelled |= emit_batch(observer, generations_run, children.len(), &ctx).is_break();

            // NSGA-III replacement over parents + children. Survivors are
            // *moved* out of the pool, never cloned, so retention copies no
            // genomes and no plans (`tests/batch_eval.rs` asserts the
            // underlying operations — Solution moves and plan-handle clones
            // — are plan-copy-free). The selection scratch (`objs`, `keep`,
            // the retained Vec) still allocates per generation — that lives
            // with the NSGA-III O(n²) ROADMAP item.
            let mut pool = std::mem::take(&mut evaluated);
            pool.extend(children);
            let objs: Vec<Vec<f64>> = pool.iter().map(|s| s.objectives.clone()).collect();
            let mut keep = nsga3_select(&objs, self.config.population);
            keep.sort_unstable();
            keep.dedup();
            evaluated = take_by_index(pool, &keep);

            // Convergence check on the average aggregate.
            let avg = avg_score(&evaluated);
            if avg < best_avg * 0.999 {
                best_avg = avg;
                stale = 0;
            } else {
                stale += 1;
            }
            cancelled |=
                emit_progress(observer, generations_run, &evaluated, avg, stale, &ctx).is_break();
            if cancelled || stale >= self.config.patience {
                break;
            }
        }

        // Final Pareto front (moved, not cloned).
        let objs: Vec<Vec<f64>> = evaluated.iter().map(|s| s.objectives.clone()).collect();
        let fronts = fast_non_dominated_sort(&objs);
        let mut front = fronts.first().cloned().unwrap_or_default();
        front.sort_unstable();
        front.dedup();
        let pareto = take_by_index(evaluated, &front);
        let (hits, misses) = profiler.stats();
        let (plan_hits, plan_misses) = plan_cache.stats();
        AnalysisResult {
            pareto,
            generations_run,
            evaluations: evals.load(Ordering::Relaxed),
            profile_cache_hits: hits,
            profile_measurements: misses,
            plan_cache_hits: plan_hits,
            plan_cache_misses: plan_misses,
            cancelled,
        }
    }

    /// Apply the chromosome-ablation switches to a genome in place.
    fn enforce_ablation_switches(&self, g: &mut Genome) {
        if !self.config.explore_partition {
            for genes in &mut g.networks {
                genes.cuts.iter_mut().for_each(|c| *c = false);
            }
        }
        if !self.config.explore_priority {
            g.priority = (0..g.priority.len()).collect();
        }
    }

    /// Seed genome: each network whole, on its individually fastest
    /// processor (a "best mapping"-like starting point).
    fn best_mapping_seed(&self) -> Genome {
        let nets = &self.scenario.networks;
        let mut genome = Genome::all_on(nets, Processor::Npu);
        for (i, net) in nets.iter().enumerate() {
            let all: Vec<crate::graph::LayerId> =
                (0..net.num_layers()).map(crate::graph::LayerId).collect();
            let best = Processor::ALL
                .into_iter()
                .min_by(|&a, &b| {
                    let ta = self.perf.best_config_for(net, &all, a).1;
                    let tb = self.perf.best_config_for(net, &all, b).1;
                    ta.partial_cmp(&tb).unwrap()
                })
                .unwrap();
            genome.networks[i] = crate::ga::NetworkGenes::whole_on(net, best);
        }
        genome
    }
}

/// Move the solutions at `indices` (strictly increasing, deduplicated) out
/// of `pool`, dropping the rest. No `Solution` is ever cloned — with
/// `Arc<PlanSet>` plan sharing this keeps survivor retention free of plan
/// copies.
fn take_by_index(pool: Vec<Solution>, indices: &[usize]) -> Vec<Solution> {
    let mut out = Vec::with_capacity(indices.len());
    let mut next = indices.iter().copied().peekable();
    for (i, sol) in pool.into_iter().enumerate() {
        if next.peek() == Some(&i) {
            next.next();
            out.push(sol);
        }
    }
    out
}

/// Send one [`crate::api::BatchProgress`] snapshot (after a batch of
/// candidate evaluations; mid-generation granularity).
fn emit_batch(
    observer: &mut dyn crate::api::Observer,
    generation: usize,
    batch_size: usize,
    ctx: &EvalCtx<'_, '_>,
) -> std::ops::ControlFlow<()> {
    observer.on_batch(&crate::api::BatchProgress {
        generation,
        batch_size,
        evaluations: ctx.evals.load(Ordering::Relaxed),
    })
}

/// Build and send one [`crate::api::GenerationProgress`] snapshot.
#[allow(clippy::too_many_arguments)]
fn emit_progress(
    observer: &mut dyn crate::api::Observer,
    generation: usize,
    evaluated: &[Solution],
    avg_aggregate: f64,
    stale_generations: usize,
    ctx: &EvalCtx<'_, '_>,
) -> std::ops::ControlFlow<()> {
    let best = evaluated
        .iter()
        .min_by(|a, b| a.max_objective().partial_cmp(&b.max_objective()).unwrap());
    let (profile_cache_hits, profile_measurements) = ctx.profiler.stats();
    let (plan_cache_hits, plan_cache_misses) = ctx.cache.stats();
    let progress = crate::api::GenerationProgress {
        generation,
        evaluations: ctx.evals.load(Ordering::Relaxed),
        best_objectives: best.map(|s| s.objectives.as_slice()).unwrap_or(&[]),
        avg_aggregate,
        stale_generations,
        profile_cache_hits,
        profile_measurements,
        plan_cache_hits,
        plan_cache_misses,
    };
    observer.on_generation(&progress)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn tiny_scenario() -> Scenario {
        Scenario::from_groups("tiny", &[vec![0, 1, 6]])
    }

    /// In-crate shorthand for the engine path (external callers go through
    /// `puzzle::api`).
    fn run(s: &Scenario, pm: &PerfModel, config: GaConfig) -> AnalysisResult {
        StaticAnalyzer::engine(s, pm, config).run_observed(&mut crate::api::null_observer())
    }

    #[test]
    fn analyzer_produces_pareto_front() {
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let result = run(&s, &pm, GaConfig::quick(1));
        assert!(!result.pareto.is_empty());
        assert!(result.evaluations > 16);
        // Pareto front is mutually non-dominated.
        for a in &result.pareto {
            for b in &result.pareto {
                assert_ne!(
                    crate::ga::fast_non_dominated_sort(&[a.objectives.clone(), b.objectives.clone()]).len() == 2
                        && a.objectives.iter().zip(&b.objectives).all(|(x, y)| x <= y)
                        && a.objectives != b.objectives,
                    true,
                    "dominated pair kept in pareto set"
                );
            }
        }
    }

    #[test]
    fn analyzer_beats_or_matches_all_cpu_seed() {
        // The search must at least rediscover something no worse than
        // running everything on the CPU.
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let analyzer = StaticAnalyzer::engine(&s, &pm, GaConfig::quick(2));
        let result = analyzer.run_observed(&mut crate::api::null_observer());
        let profiler = Profiler::new(&pm);
        let groups = analyzer.groups();
        let cpu = Genome::all_on(&s.networks, Processor::Cpu);
        let (cpu_objs, _) = analyzer.evaluate(&cpu, &profiler, &groups);
        let best = result.best_by_max_makespan();
        assert!(
            best.objectives[0] <= cpu_objs[0] * 1.05,
            "GA ({:?}) worse than all-CPU ({:?})",
            best.objectives, cpu_objs
        );
    }

    #[test]
    fn cache_reuse_is_substantial() {
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let result = run(&s, &pm, GaConfig::quick(3));
        assert!(
            result.profile_cache_hits > result.profile_measurements,
            "merkle cache ineffective: {} hits vs {} measures",
            result.profile_cache_hits, result.profile_measurements
        );
    }

    #[test]
    fn deterministic_for_seed() {
        let s = tiny_scenario();
        let pm = PerfModel::paper_calibrated();
        let r1 = run(&s, &pm, GaConfig::quick(7));
        let r2 = run(&s, &pm, GaConfig::quick(7));
        let o1: Vec<&Vec<f64>> = r1.pareto.iter().map(|s| &s.objectives).collect();
        let o2: Vec<&Vec<f64>> = r2.pareto.iter().map(|s| &s.objectives).collect();
        assert_eq!(o1, o2);
    }
}
