//! The two heuristic baselines of the evaluation (paper §6.1).
//!
//! * **NPU Only** — every model runs whole on the NPU ("highly optimized for
//!   neural network inference and generally offers the best performance").
//! * **Best Mapping** — a search-based heuristic: profile each model whole
//!   on each processor, then search model→processor mappings for Pareto
//!   points of a **profile-based estimate**. It accounts for which models
//!   share a processor but performs **no partitioning**, no priority
//!   exploration, no contention/fluctuation modeling — exactly the paper's
//!   characterization (§6.1, §6.3).

use crate::comm::CommModel;
use crate::coordinator::NetworkSolution;
use crate::ga::{decode, fast_non_dominated_sort, Genome, NetworkGenes};
use crate::perf::PerfModel;
use crate::profiler::Profiler;
use crate::scenario::Scenario;
use crate::sim::{simulate, ExecutionPlan, GroupSpec, SimOptions};
use crate::Processor;

/// A baseline solution: plans ready for the simulator/runtime.
#[derive(Debug, Clone)]
pub struct BaselineSolution {
    pub genome: Genome,
    pub plans: Vec<ExecutionPlan>,
    pub objectives: Vec<f64>,
}

impl BaselineSolution {
    /// Materialize this baseline for the runtime — the entry into the same
    /// arrival-driven serving harness ([`crate::serve`]) Puzzle's Pareto
    /// solutions go through, so saturation comparisons are apples-to-apples.
    pub fn runtime_solutions(
        &self,
        scenario: &Scenario,
        perf: &PerfModel,
    ) -> Vec<NetworkSolution> {
        crate::serve::materialize_solutions(&scenario.networks, &self.genome, perf)
    }
}

fn eval_mapping(
    scenario: &Scenario,
    mapping: &[Processor],
    profiler: &Profiler<'_>,
    comm: &CommModel,
    groups: &[GroupSpec],
    sim_requests: usize,
) -> BaselineSolution {
    let mut genome = Genome::all_on(&scenario.networks, Processor::Npu);
    for (i, net) in scenario.networks.iter().enumerate() {
        genome.networks[i] = NetworkGenes::whole_on(net, mapping[i]);
    }
    let plans = decode(&scenario.networks, &genome, profiler, comm);
    let opts = SimOptions { requests_per_group: sim_requests, ..Default::default() };
    let result = simulate(&plans, groups, comm, &opts);
    let mut objectives = Vec::with_capacity(groups.len() * 2);
    for g in 0..groups.len() {
        objectives.push(result.avg_makespan(g));
        objectives.push(result.p90_makespan(g));
    }
    BaselineSolution { genome, plans, objectives }
}

fn group_specs(scenario: &Scenario, periods: &[f64]) -> Vec<GroupSpec> {
    scenario
        .groups
        .iter()
        .zip(periods)
        .map(|(g, &p)| GroupSpec::periodic(g.members.clone(), p))
        .collect()
}

/// NPU Only: all models whole on the NPU.
pub fn npu_only(scenario: &Scenario, perf: &PerfModel, sim_requests: usize) -> BaselineSolution {
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(perf);
    let periods = scenario.periods(1.0, perf);
    let groups = group_specs(scenario, &periods);
    let mapping = vec![Processor::Npu; scenario.networks.len()];
    eval_mapping(scenario, &mapping, &profiler, &comm, &groups, sim_requests)
}

/// Profile-based makespan estimate for a mapping — Best Mapping's own view
/// of the world. Per group: models on different processors overlap, models
/// sharing a processor serialize, so the estimated group makespan is the
/// largest per-processor sum of member model times. Cross-group contention,
/// communication, and execution-time fluctuation are all ignored — exactly
/// the blind spots the paper attributes to this baseline (§6.3: "relies
/// solely on model profiling, neglecting potential contention for shared
/// resources").
fn estimate_mapping(
    scenario: &Scenario,
    mapping: &[Processor],
    model_times: &[[f64; 3]],
) -> Vec<f64> {
    scenario
        .groups
        .iter()
        .flat_map(|g| {
            let mut load = [0.0f64; 3];
            for &m in &g.members {
                load[mapping[m].index()] += model_times[m][mapping[m].index()];
            }
            let makespan = load.iter().cloned().fold(0.0, f64::max);
            // avg == p90 under the estimate (no queueing model).
            [makespan, makespan]
        })
        .collect()
}

/// Best Mapping: exhaustive search over whole-model processor mappings,
/// scored by the **profile-based estimate** above (NOT the simulator — the
/// paper's baseline adjusts mappings "based on execution times" from
/// profiling). The Pareto set under that estimate is then materialized into
/// executable plans; its real performance is whatever the evaluation
/// harness measures, contention and fluctuation included.
pub fn best_mapping(
    scenario: &Scenario,
    perf: &PerfModel,
    sim_requests: usize,
) -> Vec<BaselineSolution> {
    let comm = CommModel::paper_calibrated();
    let profiler = Profiler::new(perf);
    let periods = scenario.periods(1.0, perf);
    let groups = group_specs(scenario, &periods);
    let n = scenario.networks.len();

    // Whole-model profile per processor (what the baseline measures).
    let model_times: Vec<[f64; 3]> = scenario
        .networks
        .iter()
        .map(|net| {
            let all: Vec<crate::graph::LayerId> =
                (0..net.num_layers()).map(crate::graph::LayerId).collect();
            let mut t = [0.0f64; 3];
            for p in Processor::ALL {
                t[p.index()] = perf.best_config_for(net, &all, p).1;
            }
            t
        })
        .collect();

    assert!(n <= 10, "exhaustive mapping search over 3^{n}");
    let total = 3usize.pow(n as u32);
    let mut mappings: Vec<Vec<Processor>> = Vec::with_capacity(total);
    let mut estimates: Vec<Vec<f64>> = Vec::with_capacity(total);
    for code in 0..total {
        let mut c = code;
        let mapping: Vec<Processor> = (0..n)
            .map(|_| {
                let p = Processor::from_index(c % 3);
                c /= 3;
                p
            })
            .collect();
        estimates.push(estimate_mapping(scenario, &mapping, &model_times));
        mappings.push(mapping);
    }

    // Pareto front under the baseline's own estimate.
    let fronts = fast_non_dominated_sort(&estimates);
    let mut front: Vec<usize> = fronts.first().cloned().unwrap_or_default();
    // Deduplicate identical estimate vectors (symmetry: GPU/CPU swaps of
    // idle processors produce equal estimates) and cap the set.
    front.sort_by(|&a, &b| {
        estimates[a]
            .iter()
            .sum::<f64>()
            .partial_cmp(&estimates[b].iter().sum::<f64>())
            .unwrap()
    });
    let mut seen: Vec<Vec<u64>> = Vec::new();
    let mut chosen = Vec::new();
    for &i in &front {
        let key: Vec<u64> = estimates[i].iter().map(|v| v.to_bits()).collect();
        if !seen.contains(&key) {
            seen.push(key);
            chosen.push(i);
        }
        if chosen.len() >= 8 {
            break;
        }
    }

    chosen
        .into_iter()
        .map(|i| eval_mapping(scenario, &mappings[i], &profiler, &comm, &groups, sim_requests))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::Scenario;

    fn scen() -> Scenario {
        Scenario::from_groups("b", &[vec![0, 4, 6]])
    }

    #[test]
    fn npu_only_maps_everything_to_npu() {
        let s = scen();
        let pm = PerfModel::paper_calibrated();
        let sol = npu_only(&s, &pm, 10);
        for plan in &sol.plans {
            assert_eq!(plan.tasks.len(), 1, "NPU Only must not partition");
            assert_eq!(plan.tasks[0].processor, Processor::Npu);
        }
    }

    #[test]
    fn best_mapping_is_nonempty_and_unpartitioned() {
        let s = scen();
        let pm = PerfModel::paper_calibrated();
        let front = best_mapping(&s, &pm, 10);
        assert!(!front.is_empty() && front.len() <= 8);
        // No partitioning: one task per model.
        for sol in &front {
            for plan in &sol.plans {
                assert_eq!(plan.tasks.len(), 1);
            }
        }
        // The front's best solution should spread load across processors
        // (not everything on one processor) for this heavy scenario.
        let procs: std::collections::HashSet<Processor> = front[0]
            .plans
            .iter()
            .map(|p| p.tasks[0].processor)
            .collect();
        assert!(procs.len() >= 2, "best mapping put everything on {procs:?}");
    }

    #[test]
    fn best_mapping_beats_npu_only_under_contention() {
        // With three models contending, spreading across processors must
        // achieve a lower or equal worst objective than NPU-only.
        let s = scen();
        let pm = PerfModel::paper_calibrated();
        let npu = npu_only(&s, &pm, 10);
        let front = best_mapping(&s, &pm, 10);
        let best_avg = front
            .iter()
            .map(|sol| sol.objectives[0])
            .fold(f64::INFINITY, f64::min);
        assert!(
            best_avg <= npu.objectives[0] + 1e-12,
            "best mapping {best_avg} worse than npu-only {}",
            npu.objectives[0]
        );
    }
}
