//! Seeded scenario fuzzer: generalizes the Fig-11 generator far beyond
//! the nine-model zoo.
//!
//! A [`ScenarioFuzzer`] draws [`FuzzedScenario`]s — scenario structure
//! *and* a matching [`LoadSpec`] — from a seeded [`Rng`], controlled by a
//! [`FuzzConfig`]:
//!
//! * **group counts** up to 10–100 groups ([`FuzzConfig::stress`]) to
//!   stress the coordinator's heaps;
//! * **model mixes** over the zoo plus small *generated* networks
//!   (random conv/dwconv/pointwise/pool chains outside the zoo);
//! * **SLA classes**: per-group deadlines at distinct multiples of the
//!   group period;
//! * **arrival mixes**: periodic, Poisson, bursty, plus time-varying λ
//!   schedules — diurnal ramps and flash-crowd spikes expressed as
//!   [`ArrivalProcess::Schedule`] segments; the family mix is a knob
//!   ([`FuzzConfig::patterns`] — e.g. [`FuzzConfig::calibration`] is
//!   periodic-only);
//! * **model churn**: with probability [`FuzzConfig::churn_prob`] one
//!   group joins late (its whole schedule offset to a seeded time) or
//!   leaves early (its request stream truncated at a seeded time).
//!
//! Determinism contract #7 (fuzz-corpus replay): the same `(seed, index,
//! config)` reproduces a bit-identical [`FuzzedScenario`] — every zoo
//! draw, generated layer, arrival time and deadline — so a corpus is
//! replayable across sessions and its measured reports anchor golden
//! hashes (`tests/fixtures/fuzz_corpus_v1.txt`). Every draw satisfies
//! [`LoadSpec::validate`] by construction (checked at generation time).

use crate::comm::CommModel;
use crate::coordinator::OverloadPolicy;
use crate::graph::{Layer, Network};
use crate::models;
use crate::perf::PerfModel;
use crate::serve::{ArrivalProcess, ClockMode, GroupLoad, LoadSpec, RateSegment};
use crate::util::rng::Rng;

use super::{ModelGroup, Scenario, CUSTOM_ZOO_INDEX};

/// An arrival-pattern family the fuzzer can draw for a group.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArrivalKind {
    /// Fixed-spacing arrivals at the group period.
    Periodic,
    /// Poisson arrivals at the same mean rate.
    Poisson,
    /// Burst clumps at the same long-run rate.
    Bursty,
    /// Diurnal ramp expressed as an [`ArrivalProcess::Schedule`].
    Diurnal,
    /// Flash-crowd spike expressed as an [`ArrivalProcess::Schedule`].
    FlashCrowd,
}

impl ArrivalKind {
    /// All five families — the default mix.
    pub const ALL: [ArrivalKind; 5] = [
        ArrivalKind::Periodic,
        ArrivalKind::Poisson,
        ArrivalKind::Bursty,
        ArrivalKind::Diurnal,
        ArrivalKind::FlashCrowd,
    ];
}

/// Knobs of the scenario fuzzer. All ranges are inclusive.
#[derive(Debug, Clone)]
pub struct FuzzConfig {
    /// Model-group count range per scenario.
    pub groups: (usize, usize),
    /// Members per group.
    pub members: (usize, usize),
    /// Probability a member is a generated network instead of a zoo model.
    pub generated_prob: f64,
    /// SLA classes: each group's deadline is a drawn class × its period.
    pub sla_classes: Vec<f64>,
    /// Period-multiplier range (α of the Fig-11 protocol): values below 1
    /// produce infeasible draws that exercise the certificate path.
    pub alpha: (f64, f64),
    /// Requests per group.
    pub requests: (usize, usize),
    /// Probability the scenario carries a churn event (group join/leave).
    pub churn_prob: f64,
    /// Arrival-pattern families drawn uniformly per group. Restricting the
    /// mix carves calibration corpora out of the same seeded stream (e.g.
    /// periodic-only for the admission-slack sweep).
    pub patterns: Vec<ArrivalKind>,
}

impl Default for FuzzConfig {
    fn default() -> FuzzConfig {
        FuzzConfig {
            groups: (1, 12),
            members: (1, 3),
            generated_prob: 0.25,
            sla_classes: vec![0.8, 1.0, 1.5, 2.5],
            alpha: (0.8, 4.0),
            requests: (4, 12),
            churn_prob: 0.25,
            patterns: ArrivalKind::ALL.to_vec(),
        }
    }
}

impl FuzzConfig {
    /// Heap-stress preset: 10–100 small groups per scenario.
    pub fn stress() -> FuzzConfig {
        FuzzConfig {
            groups: (10, 100),
            members: (1, 2),
            generated_prob: 0.15,
            requests: (2, 6),
            ..FuzzConfig::default()
        }
    }

    /// Smoke-test preset: small scenarios, short loads.
    pub fn quick() -> FuzzConfig {
        FuzzConfig { groups: (1, 4), members: (1, 2), requests: (3, 6), ..FuzzConfig::default() }
    }

    /// Admission-calibration preset: periodic-only arrivals at comfortably
    /// feasible α, no churn — the [`crate::serve::Admission::LittleCap`]
    /// design domain, where the slack sweep must measure zero drops.
    pub fn calibration() -> FuzzConfig {
        FuzzConfig {
            groups: (1, 8),
            members: (1, 2),
            alpha: (2.0, 4.0),
            requests: (6, 12),
            churn_prob: 0.0,
            patterns: vec![ArrivalKind::Periodic],
            ..FuzzConfig::default()
        }
    }
}

/// Which way a churn event changes a group's traffic.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ChurnKind {
    /// The group joins mid-run: its whole arrival schedule starts at the
    /// churn time (an [`ArrivalProcess::Schedule`] offset).
    Join,
    /// The group leaves mid-run: requests after the churn time are
    /// dropped from its stream (at least one request remains).
    Leave,
}

/// A seeded mid-run model-churn event applied to one group's load.
#[derive(Debug, Clone, Copy)]
pub struct ChurnEvent {
    /// Group whose traffic churns.
    pub group: usize,
    /// Join or leave.
    pub kind: ChurnKind,
    /// When it happens, simulated seconds from the load's start.
    pub time: f64,
}

/// One fuzzer draw: a scenario, the α it is loaded at, the resulting
/// [`LoadSpec`] (arrival mixes + SLA deadlines, churn already applied),
/// and the churn event for reporting.
#[derive(Debug, Clone)]
pub struct FuzzedScenario {
    /// The per-case seed every draw derives from ([`case_seed`]).
    pub seed: u64,
    /// Position of this case in its corpus.
    pub index: usize,
    /// The generated scenario (zoo + generated networks, model groups).
    pub scenario: Scenario,
    /// Period multiplier the load was drawn at.
    pub alpha: f64,
    /// The complete load (virtual clock, queue-all admission).
    pub spec: LoadSpec,
    /// Churn event applied to `spec`, if any.
    pub churn: Option<ChurnEvent>,
}

/// Per-case seed: a splitmix-style spread of the corpus base seed, stable
/// in `(base, index)` so corpora share a prefix when only `count` grows.
pub fn case_seed(base: u64, index: usize) -> u64 {
    base ^ (index as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0x5CE0_FA22
}

/// Streaming fuzzer: draws successive corpus cases.
#[derive(Debug, Clone)]
pub struct ScenarioFuzzer {
    seed: u64,
    config: FuzzConfig,
    next_index: usize,
}

impl ScenarioFuzzer {
    /// A fuzzer over `config`, deterministic in `seed`.
    pub fn new(seed: u64, config: FuzzConfig) -> ScenarioFuzzer {
        ScenarioFuzzer { seed, config, next_index: 0 }
    }

    /// Draw the next case (equals `corpus(seed, ..)[next_index]`).
    pub fn draw(&mut self, perf: &PerfModel) -> FuzzedScenario {
        let case = FuzzedScenario::generate(self.seed, self.next_index, &self.config, perf);
        self.next_index += 1;
        case
    }
}

/// Generate a whole corpus: `count` cases of `config` from `seed`.
pub fn corpus(
    seed: u64,
    count: usize,
    config: &FuzzConfig,
    perf: &PerfModel,
) -> Vec<FuzzedScenario> {
    (0..count).map(|i| FuzzedScenario::generate(seed, i, config, perf)).collect()
}

impl FuzzedScenario {
    /// Generate case `index` of the corpus rooted at `base_seed`.
    /// Bit-identical in `(base_seed, index, config)` — contract #7.
    pub fn generate(
        base_seed: u64,
        index: usize,
        config: &FuzzConfig,
        perf: &PerfModel,
    ) -> FuzzedScenario {
        let seed = case_seed(base_seed, index);
        let mut rng = Rng::seed_from_u64(seed);
        let scenario = draw_scenario(&mut rng, seed, index, config);
        let alpha = rng.gen_f64_range(config.alpha.0, config.alpha.1);
        let periods = scenario.periods(alpha, perf);

        let mut loads: Vec<GroupLoad> = periods
            .iter()
            .map(|&period| {
                let process = draw_process(&mut rng, period, &config.patterns);
                let class = *rng.choose(&config.sla_classes).unwrap_or(&1.0);
                let requests =
                    rng.gen_range_inclusive(config.requests.0.max(1), config.requests.1.max(1));
                GroupLoad { process, deadline: Some(period * class), requests }
            })
            .collect();

        let churn = draw_churn(&mut rng, config, &loads, &periods);
        if let Some(event) = churn {
            apply_churn(&mut loads, &periods, event);
        }

        let spec = LoadSpec {
            groups: loads,
            mode: ClockMode::Virtual,
            policy: OverloadPolicy::Queue,
            comm: CommModel::paper_calibrated(),
        };
        spec.validate().expect("fuzzer draws are valid by construction");
        FuzzedScenario { seed, index, scenario, alpha, spec, churn }
    }
}

/// Draw the scenario structure (groups, zoo/generated members).
fn draw_scenario(rng: &mut Rng, seed: u64, index: usize, config: &FuzzConfig) -> Scenario {
    let (g_lo, g_hi) = (config.groups.0.max(1), config.groups.1.max(config.groups.0).max(1));
    let n_groups = rng.gen_range_inclusive(g_lo, g_hi);
    let mut networks = Vec::new();
    let mut zoo_indices = Vec::new();
    let mut groups = Vec::new();
    for _ in 0..n_groups {
        let n_members =
            rng.gen_range_inclusive(config.members.0.max(1), config.members.1.max(1));
        let mut members = Vec::new();
        for _ in 0..n_members {
            let id = networks.len();
            if rng.gen_bool(config.generated_prob) {
                // Names key the profiler's per-network statistics, so a
                // generated net's name carries the case seed: structurally
                // different nets never share one.
                let name = format!("fz{seed:016x}n{id}");
                networks.push(generated_network(id, &name, rng));
                zoo_indices.push(CUSTOM_ZOO_INDEX);
            } else {
                let zoo = rng.gen_range(0, models::MODEL_COUNT);
                networks.push(models::build_model(id, zoo));
                zoo_indices.push(zoo);
            }
            members.push(id);
        }
        groups.push(ModelGroup { members });
    }
    Scenario { name: format!("fuzz-{index}"), networks, zoo_indices, groups }
}

/// A small random chain network outside the zoo: stem conv, then 3–6
/// pointwise/depthwise/strided-conv/plain-conv stages.
fn generated_network(id: usize, name: &str, rng: &mut Rng) -> Network {
    let mut net = Network::new(id, name);
    let mut size = *rng.choose(&[32usize, 64]).expect("non-empty");
    let mut channels = *rng.choose(&[8usize, 16, 24]).expect("non-empty");
    let mut prev = net.add_layer(Layer::conv("stem", size, 3, channels, 3, 1));
    let depth = rng.gen_range_inclusive(3, 6);
    for i in 0..depth {
        let lname = format!("l{i}");
        let layer = match rng.gen_range(0, 4) {
            0 => {
                let out = (channels * 2).min(64);
                let l = Layer::pointwise(&lname, size, channels, out);
                channels = out;
                l
            }
            1 => Layer::dwconv(&lname, size, channels, 3, 1),
            2 if size >= 16 => {
                let l = Layer::conv(&lname, size, channels, channels, 3, 2);
                size /= 2;
                l
            }
            _ => Layer::conv(&lname, size, channels, channels, 3, 1),
        };
        let lid = net.add_layer(layer);
        net.connect(prev, lid);
        prev = lid;
    }
    let pool = net.add_layer(Layer::pool("head", size, channels));
    net.connect(prev, pool);
    net.finalize();
    net
}

/// Draw one group's arrival process around its period: uniform over the
/// configured [`ArrivalKind`] families.
fn draw_process(rng: &mut Rng, period: f64, patterns: &[ArrivalKind]) -> ArrivalProcess {
    let kind = rng.choose(patterns).copied().unwrap_or(ArrivalKind::Periodic);
    match kind {
        ArrivalKind::Periodic => ArrivalProcess::Periodic { period },
        ArrivalKind::Poisson => ArrivalProcess::Poisson { mean: period, seed: rng.next_u64() },
        ArrivalKind::Bursty => {
            ArrivalProcess::Bursty { period, burst: rng.gen_range_inclusive(2, 5) }
        }
        ArrivalKind::Diurnal => diurnal(rng, period),
        ArrivalKind::FlashCrowd => flash_crowd(rng, period),
    }
}

/// Diurnal ramp: four phases — off-peak, shoulder, peak (up to 2× the
/// base rate), shoulder — cycled.
fn diurnal(rng: &mut Rng, period: f64) -> ArrivalProcess {
    let peak = rng.gen_f64_range(1.3, 2.0);
    let phase = period * rng.gen_range_inclusive(2, 4) as f64;
    ArrivalProcess::Schedule {
        segments: vec![
            RateSegment::new(phase, period * 1.5),
            RateSegment::new(phase, period),
            RateSegment::new(phase, period / peak),
            RateSegment::new(phase, period),
        ],
        offset: 0.0,
    }
}

/// Flash crowd: a long quiet stretch slightly under the base rate, then a
/// short spike at 2–4× the base rate.
fn flash_crowd(rng: &mut Rng, period: f64) -> ArrivalProcess {
    let spike = rng.gen_f64_range(2.0, 4.0);
    let quiet = period * rng.gen_range_inclusive(4, 8) as f64;
    let crowd = period * rng.gen_range_inclusive(1, 2) as f64;
    ArrivalProcess::Schedule {
        segments: vec![
            RateSegment::new(quiet, period * 1.25),
            RateSegment::new(crowd, period / spike),
        ],
        offset: 0.0,
    }
}

/// Draw an optional churn event: multi-group scenarios only, landing in
/// the middle half of the load's horizon.
fn draw_churn(
    rng: &mut Rng,
    config: &FuzzConfig,
    loads: &[GroupLoad],
    periods: &[f64],
) -> Option<ChurnEvent> {
    if loads.len() < 2 || !rng.gen_bool(config.churn_prob) {
        return None;
    }
    let horizon = loads
        .iter()
        .zip(periods)
        .map(|(l, &p)| l.requests as f64 * p)
        .fold(0.0f64, f64::max);
    let group = rng.gen_range(0, loads.len());
    let kind = if rng.gen_bool(0.5) { ChurnKind::Join } else { ChurnKind::Leave };
    let time = rng.gen_f64_range(0.25, 0.75) * horizon;
    Some(ChurnEvent { group, kind, time })
}

/// Apply a churn event to the drawn loads: a join re-expresses the
/// group's stream as a schedule offset to the churn time; a leave
/// truncates its request count to the arrivals before it.
fn apply_churn(loads: &mut [GroupLoad], periods: &[f64], event: ChurnEvent) {
    let load = &mut loads[event.group];
    let period = periods[event.group];
    match event.kind {
        ChurnKind::Join => {
            let span = (load.requests as f64 * period).max(period);
            load.process = ArrivalProcess::Schedule {
                segments: vec![RateSegment::new(span, period)],
                offset: event.time,
            };
        }
        ChurnKind::Leave => {
            let kept =
                load.process.times(load.requests).iter().filter(|&&t| t < event.time).count();
            load.requests = kept.max(1);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_draw() {
        let pm = PerfModel::paper_calibrated();
        let config = FuzzConfig::quick();
        let a = FuzzedScenario::generate(7, 3, &config, &pm);
        let b = FuzzedScenario::generate(7, 3, &config, &pm);
        assert_eq!(a.scenario.zoo_indices, b.scenario.zoo_indices);
        assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        for (x, y) in a.spec.groups.iter().zip(&b.spec.groups) {
            assert_eq!(x.requests, y.requests);
            assert_eq!(x.deadline.map(f64::to_bits), y.deadline.map(f64::to_bits));
            let (tx, ty) = (x.process.times(x.requests), y.process.times(y.requests));
            assert_eq!(tx.len(), ty.len());
            for (s, t) in tx.iter().zip(&ty) {
                assert_eq!(s.to_bits(), t.to_bits());
            }
        }
    }

    #[test]
    fn corpus_is_prefix_stable() {
        let pm = PerfModel::paper_calibrated();
        let config = FuzzConfig::quick();
        let small = corpus(11, 3, &config, &pm);
        let large = corpus(11, 5, &config, &pm);
        for (a, b) in small.iter().zip(&large) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.scenario.zoo_indices, b.scenario.zoo_indices);
            assert_eq!(a.alpha.to_bits(), b.alpha.to_bits());
        }
    }

    #[test]
    fn draws_respect_config_ranges_and_validate() {
        let pm = PerfModel::paper_calibrated();
        let config = FuzzConfig {
            groups: (2, 5),
            members: (1, 2),
            requests: (3, 6),
            ..FuzzConfig::default()
        };
        for i in 0..12 {
            let case = FuzzedScenario::generate(23, i, &config, &pm);
            let n = case.scenario.groups.len();
            assert!((2..=5).contains(&n), "group count {n} outside configured range");
            for g in &case.scenario.groups {
                assert!((1..=2).contains(&g.members.len()));
            }
            assert!(case.spec.validate().is_ok());
            assert!(case.alpha >= config.alpha.0 && case.alpha <= config.alpha.1);
            for load in &case.spec.groups {
                assert!((3..=6).contains(&load.requests) || case.churn.is_some());
            }
        }
    }

    #[test]
    fn pattern_knob_restricts_the_arrival_mix() {
        let pm = PerfModel::paper_calibrated();
        let config = FuzzConfig::calibration();
        for i in 0..8 {
            let case = FuzzedScenario::generate(41, i, &config, &pm);
            for load in &case.spec.groups {
                assert!(
                    matches!(load.process, ArrivalProcess::Periodic { .. }),
                    "calibration preset drew a non-periodic process"
                );
            }
        }
    }

    #[test]
    fn stress_preset_reaches_large_group_counts() {
        let pm = PerfModel::paper_calibrated();
        let config = FuzzConfig { generated_prob: 0.0, ..FuzzConfig::stress() };
        let max = (0..6)
            .map(|i| FuzzedScenario::generate(5, i, &config, &pm).scenario.groups.len())
            .max()
            .expect("non-empty");
        assert!(max >= 10, "stress preset never exceeded 10 groups (max {max})");
    }

    #[test]
    fn leave_churn_truncates_and_join_churn_offsets() {
        let pm = PerfModel::paper_calibrated();
        let config = FuzzConfig { churn_prob: 1.0, groups: (2, 4), ..FuzzConfig::quick() };
        let mut seen_join = false;
        let mut seen_leave = false;
        for i in 0..24 {
            let case = FuzzedScenario::generate(99, i, &config, &pm);
            let Some(event) = case.churn else { continue };
            let load = &case.spec.groups[event.group];
            match event.kind {
                ChurnKind::Join => {
                    seen_join = true;
                    let first = load.process.times(1)[0];
                    assert!(
                        (first - event.time).abs() < 1e-9,
                        "joined group must start at the churn time"
                    );
                }
                ChurnKind::Leave => {
                    seen_leave = true;
                    let times = load.process.times(load.requests);
                    let late = times.iter().filter(|&&t| t >= event.time).count();
                    assert!(
                        late == 0 || load.requests == 1,
                        "left group still arrives after the churn time"
                    );
                }
            }
        }
        assert!(seen_join && seen_leave, "24 churn draws never produced both kinds");
    }
}
