//! Scenarios: model groups, periods, and the random scenario generator
//! (paper §6.1, Fig 11).
//!
//! A scenario is a set of *model groups* — models fed by one synchronized
//! input source (camera, microphone) and requested periodically. The paper
//! evaluates 10 single-group scenarios (6 random models each) and 10
//! two-group scenarios (3 + 3 models), with each group's **base period**
//!
//! ```text
//! φ̄_Gi = Σ_{m∈Gi} min_p τ_p(m) · N · (1 + ε)        (ε = 0.1)
//! ```
//!
//! scaled by a *period multiplier* α to tighten/relax the SLO.

pub mod fuzz;

use crate::util::rng::Rng;
use crate::graph::{LayerId, Network};
use crate::perf::PerfModel;
use crate::{models, Processor};

/// Slack constant ε in the base-period formula (paper: 0.1).
pub const EPSILON: f64 = 0.1;

/// Sentinel `zoo_indices` entry for networks built outside the model zoo
/// (see [`Scenario::from_networks`]).
pub const CUSTOM_ZOO_INDEX: usize = usize::MAX;

/// One model group: zoo indices + which scenario networks belong to it.
#[derive(Debug, Clone)]
pub struct ModelGroup {
    /// Indices into the scenario's `networks`.
    pub members: Vec<usize>,
}

/// A full evaluation scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    /// Instantiated networks (network ids = position).
    pub networks: Vec<Network>,
    /// Zoo index of each network (for reporting).
    pub zoo_indices: Vec<usize>,
    pub groups: Vec<ModelGroup>,
}

impl Scenario {
    /// Build a scenario from zoo indices grouped into model groups.
    pub fn from_groups(name: &str, groups: &[Vec<usize>]) -> Scenario {
        let mut networks = Vec::new();
        let mut zoo_indices = Vec::new();
        let mut out_groups = Vec::new();
        for group in groups {
            let mut members = Vec::new();
            for &zoo in group {
                members.push(networks.len());
                networks.push(models::build_model(networks.len(), zoo));
                zoo_indices.push(zoo);
            }
            out_groups.push(ModelGroup { members });
        }
        Scenario { name: name.to_string(), networks, zoo_indices, groups: out_groups }
    }

    /// Build a scenario from caller-provided networks (models outside the
    /// zoo — [`crate::api::ScenarioSpec::Custom`]). `groups` partitions the
    /// network indices into model groups. Custom networks have no zoo entry,
    /// so their `zoo_indices` are the [`CUSTOM_ZOO_INDEX`] sentinel.
    pub fn from_networks(name: &str, networks: Vec<Network>, groups: &[Vec<usize>]) -> Scenario {
        let zoo_indices = vec![CUSTOM_ZOO_INDEX; networks.len()];
        let out_groups = groups
            .iter()
            .map(|g| ModelGroup { members: g.clone() })
            .collect();
        Scenario { name: name.to_string(), networks, zoo_indices, groups: out_groups }
    }

    /// Base period φ̄ for one group (seconds): sum over members of the
    /// fastest-processor whole-model time, times N·(1+ε).
    pub fn base_period(&self, group: usize, pm: &PerfModel) -> f64 {
        let n_groups = self.groups.len() as f64;
        let sum: f64 = self.groups[group]
            .members
            .iter()
            .map(|&m| {
                let net = &self.networks[m];
                let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
                Processor::ALL
                    .iter()
                    .map(|&p| pm.best_config_for(net, &all, p).1)
                    .fold(f64::INFINITY, f64::min)
            })
            .sum();
        sum * n_groups * (1.0 + EPSILON)
    }

    /// Period Φ(α, Gi) = α · φ̄ for every group.
    pub fn periods(&self, alpha: f64, pm: &PerfModel) -> Vec<f64> {
        (0..self.groups.len()).map(|g| alpha * self.base_period(g, pm)).collect()
    }

    pub fn num_models(&self) -> usize {
        self.networks.len()
    }
}

/// Generate the paper's 10 single-group scenarios: each draws 6 distinct
/// models from the nine-model zoo (Fig 11 top). Deterministic in `seed`.
pub fn single_group_scenarios(seed: u64) -> Vec<Scenario> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..10)
        .map(|i| {
            let mut idx: Vec<usize> = (0..models::MODEL_COUNT).collect();
            rng.shuffle(&mut idx);
            let chosen: Vec<usize> = idx[..6].to_vec();
            Scenario::from_groups(&format!("single-{}", i + 1), &[chosen])
        })
        .collect()
}

/// Generate the paper's 10 multi-group scenarios: two groups of 3 models
/// (Fig 11 bottom; "maintaining the same settings as in the single model
/// group experiments" — same total of six models per scenario).
pub fn multi_group_scenarios(seed: u64) -> Vec<Scenario> {
    let mut rng = Rng::seed_from_u64(seed);
    (0..10)
        .map(|i| {
            let mut idx: Vec<usize> = (0..models::MODEL_COUNT).collect();
            rng.shuffle(&mut idx);
            let g1: Vec<usize> = idx[..3].to_vec();
            let g2: Vec<usize> = idx[3..6].to_vec();
            Scenario::from_groups(&format!("multi-{}", i + 1), &[g1, g2])
        })
        .collect()
}

/// The paper's Scenario 6 analog (§6.4): five MediaPipe models + YOLOv8 in
/// two groups — all models NPU-friendly and lightweight except YOLOv8.
pub fn scenario6_analog() -> Scenario {
    Scenario::from_groups("scenario-6", &[vec![0, 1, 2], vec![3, 0, 6]])
}

/// The paper's Scenario 10 analog (§6.4): one lightweight group (MediaPipe
/// series) and one heavy group (YOLOv8, Fast-SCNN, TCMonoDepth).
pub fn scenario10_analog() -> Scenario {
    Scenario::from_groups("scenario-10", &[vec![0, 1, 3], vec![6, 5, 4]])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_group_shapes() {
        let ss = single_group_scenarios(23);
        assert_eq!(ss.len(), 10);
        for s in &ss {
            assert_eq!(s.groups.len(), 1);
            assert_eq!(s.num_models(), 6);
            // Distinct zoo models per scenario.
            let mut z = s.zoo_indices.clone();
            z.sort();
            z.dedup();
            assert_eq!(z.len(), 6);
        }
    }

    #[test]
    fn multi_group_shapes() {
        let ss = multi_group_scenarios(23);
        assert_eq!(ss.len(), 10);
        for s in &ss {
            assert_eq!(s.groups.len(), 2);
            assert_eq!(s.groups[0].members.len(), 3);
            assert_eq!(s.groups[1].members.len(), 3);
        }
    }

    #[test]
    fn determinism_in_seed() {
        let a = single_group_scenarios(7);
        let b = single_group_scenarios(7);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.zoo_indices, y.zoo_indices);
        }
        let c = single_group_scenarios(8);
        assert!(a.iter().zip(&c).any(|(x, y)| x.zoo_indices != y.zoo_indices));
    }

    #[test]
    fn base_period_formula() {
        // Single network, single group: φ̄ = min_p τ_p(m) · 1 · 1.1.
        let pm = PerfModel::paper_calibrated();
        let s = Scenario::from_groups("t", &[vec![0]]);
        let net = &s.networks[0];
        let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
        let fastest = Processor::ALL
            .iter()
            .map(|&p| pm.best_config_for(net, &all, p).1)
            .fold(f64::INFINITY, f64::min);
        let expected = fastest * 1.1;
        assert!((s.base_period(0, &pm) - expected).abs() < 1e-12);
    }

    #[test]
    fn multi_group_period_scales_with_n() {
        let pm = PerfModel::paper_calibrated();
        let single = Scenario::from_groups("a", &[vec![0, 1, 2]]);
        let multi = Scenario::from_groups("b", &[vec![0, 1, 2], vec![3, 4, 5]]);
        // Same members in group 0, but N=2 doubles the slack multiplier.
        let p1 = single.base_period(0, &pm);
        let p2 = multi.base_period(0, &pm);
        assert!((p2 / p1 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn alpha_scales_periods() {
        let pm = PerfModel::paper_calibrated();
        let s = scenario10_analog();
        let p1 = s.periods(1.0, &pm);
        let p2 = s.periods(0.5, &pm);
        for (a, b) in p1.iter().zip(&p2) {
            assert!((b / a - 0.5).abs() < 1e-9);
        }
    }
}
