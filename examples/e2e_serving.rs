//! END-TO-END VALIDATION (DESIGN.md §7): the full three-layer stack on a
//! real workload.
//!
//! 1. Loads the **real AOT HLO artifacts** (python/jax/Pallas → HLO text,
//!    built by `make artifacts`) for a model group through the PJRT CPU
//!    client — Python is *not* running; the rust binary executes the
//!    compiled XLA computations directly.
//! 2. Runs the Static Analyzer to pick a partition/mapping/priority
//!    solution for the group.
//! 3. Serves periodic batched group requests through the full
//!    Coordinator → Worker → PjrtEngine path, with tensor pool and
//!    zero-copy shared buffer enabled.
//! 4. Reports latency (avg/p50/p90 makespan), throughput, and per-model
//!    output checksums. Recorded in EXPERIMENTS.md §E2E.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_serving`

use std::sync::Arc;
use std::time::{Duration, Instant};

use puzzle::analyzer::GaConfig;
use puzzle::api::{RuntimeOptions, ScenarioSpec, SessionBuilder};
use puzzle::engine::{Engine, PjrtEngine};
use puzzle::runtime::{model_artifact, PjrtRuntime};

fn main() {
    if !model_artifact("face_det").exists() {
        eprintln!("artifacts not found — run `make artifacts` first");
        std::process::exit(1);
    }

    // A realistic camera-pipeline group: face detection + selfie
    // segmentation + hand detection (the paper's motivating example).
    let session = SessionBuilder::new(ScenarioSpec::single_group("e2e", vec![0, 1, 2]))
        .config(GaConfig::quick(7))
        .build()
        .expect("valid scenario spec");
    println!("== Static Analyzer ==");
    let analysis = session.run();
    let best_idx = analysis.best_index();
    let best = &analysis.pareto[best_idx];
    println!(
        "{} generations, {} evaluations, chose objectives {:?}",
        analysis.generations_run,
        analysis.evaluations,
        best.objectives.iter().map(|o| format!("{:.2}ms", o * 1e3)).collect::<Vec<_>>()
    );

    // Preload every artifact through PJRT, then deploy onto the real
    // engine; the deployment materializes the runtime solutions once.
    println!("== PJRT initialization ==");
    let t0 = Instant::now();
    let runtime = PjrtRuntime::cpu().expect("pjrt cpu client");
    println!("platform: {}", runtime.platform());
    let engine_impl = Arc::new(PjrtEngine::new(runtime));
    for net in &session.scenario().networks {
        engine_impl.preload(net).expect("preload artifacts");
    }
    println!(
        "compiled {} executables in {:.2}s",
        engine_impl.cached_modules(),
        t0.elapsed().as_secs_f64()
    );

    // Serve periodic requests: the group "camera" ticks every period.
    println!("== Serving ==");
    let engine: Arc<dyn Engine> = engine_impl;
    let mut deployment = analysis
        .deploy_with_engine(best_idx, RuntimeOptions::default(), engine, 1.0)
        .expect("deployable solution");
    for sol in deployment.coordinator.solutions() {
        println!(
            "  {}: {} subgraphs ({:?})",
            sol.network.name,
            sol.partition.num_subgraphs(),
            sol.partition
                .subgraphs
                .iter()
                .map(|s| (s.layers.len(), s.processor))
                .collect::<Vec<_>>()
        );
    }
    let requests = 200usize;
    let period = Duration::from_millis(5);
    let t0 = Instant::now();
    for j in 0..requests {
        let target = period * j as u32;
        if let Some(sleep) = target.checked_sub(t0.elapsed()) {
            std::thread::sleep(sleep);
        }
        deployment.serve(0, 1, Duration::from_secs(5));
    }
    let wall = t0.elapsed().as_secs_f64();

    let coord = &deployment.coordinator;
    let mut makespans: Vec<f64> = coord.served().iter().map(|s| s.makespan).collect();
    makespans.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (avg, sd) = puzzle::metrics::mean_sd(&makespans);
    let (m_ms, m_n, c_ms, f_ms) = coord.pool_stats();
    println!("served {}/{} group requests in {:.2}s wall", makespans.len(), requests, wall);
    println!(
        "makespan: avg {:.2} ± {:.2} ms, p50 {:.2} ms, p90 {:.2} ms, max {:.2} ms",
        avg * 1e3,
        sd * 1e3,
        puzzle::sim::percentile(&makespans, 0.5) * 1e3,
        puzzle::sim::percentile(&makespans, 0.9) * 1e3,
        makespans.last().copied().unwrap_or(0.0) * 1e3
    );
    println!(
        "throughput: {:.1} group-requests/s ({:.1} model inferences/s)",
        makespans.len() as f64 / wall,
        makespans.len() as f64 * 3.0 / wall
    );
    println!(
        "tensor pool: malloc {:.2} ms over {} allocs, memcpy {:.2} ms, free {:.2} ms",
        m_ms, m_n, c_ms, f_ms
    );
    deployment.shutdown();
    println!("e2e OK");
}
