//! Quickstart: partition one model, inspect the plan, and serve a few
//! requests through the runtime with the calibrated simulated device.
//!
//! Run with: `cargo run --release --example quickstart`

use std::sync::Arc;

use puzzle::coordinator::{Coordinator, NetworkSolution, RuntimeOptions};
use puzzle::engine::{Engine, SimEngine};
use puzzle::ga::{decode_network, NetworkGenes};
use puzzle::graph::LayerId;
use puzzle::models::build_model;
use puzzle::perf::PerfModel;
use puzzle::Processor;

fn main() {
    let pm = PerfModel::paper_calibrated();

    // 1. A model from the zoo: the YOLOv8-nano analog.
    let net = build_model(0, 6);
    println!("model {}: {} layers, {} edges, {:.1}M MACs", net.name, net.num_layers(), net.num_edges(), net.total_macs() as f64 / 1e6);

    // 2. Profile it whole on each processor (Table 3 view).
    let all: Vec<LayerId> = (0..net.num_layers()).map(LayerId).collect();
    for p in Processor::ALL {
        let (cfg, t) = pm.best_config_for(&net, &all, p);
        println!("  whole on {p}: {:.2} ms under {cfg}", t * 1e3);
    }

    // 3. Partition it: cut after the CSP join (edge 7) and map the backbone
    //    to the NPU, the heads to the GPU — the kind of solution the Static
    //    Analyzer discovers automatically.
    let mut genes = NetworkGenes::whole_on(&net, Processor::Npu);
    genes.cuts[7] = true;
    for l in 9..net.num_layers() {
        genes.mapping[l] = Processor::Gpu;
    }
    let part = decode_network(&net, &genes);
    println!("partitioned into {} subgraphs:", part.num_subgraphs());
    for sg in &part.subgraphs {
        let t = pm.subgraph_time(&net, &sg.layers, puzzle::ExecConfig::default_for(sg.processor));
        println!(
            "  {}: {} layers on {} ({:.2} ms), deps {:?}",
            sg.id, sg.layers.len(), sg.processor, t * 1e3, sg.deps
        );
    }

    // 4. Serve 10 requests through the real Coordinator/Worker stack.
    let configs = part
        .subgraphs
        .iter()
        .map(|sg| pm.best_config_for(&net, &sg.layers, sg.processor).0)
        .collect();
    let solution = NetworkSolution {
        network: Arc::new(net),
        partition: Arc::new(part),
        configs,
        priority: 0,
    };
    let time_scale = 0.1; // 1 simulated ms = 0.1 wall ms
    let engine: Arc<dyn Engine> = Arc::new(SimEngine::new(Arc::new(pm), time_scale, true, 42));
    let mut coord = Coordinator::new(vec![solution], engine, RuntimeOptions::default());
    for _ in 0..10 {
        coord.submit_group(0, &[0]);
        coord.pump(std::time::Duration::from_secs(10));
    }
    let makespans: Vec<f64> = coord.served().iter().map(|s| s.makespan / time_scale).collect();
    let (avg, sd) = puzzle::metrics::mean_sd(&makespans);
    println!(
        "served {} requests: simulated makespan {:.2} ± {:.2} ms",
        makespans.len(), avg * 1e3, sd * 1e3
    );
    coord.shutdown();
}
