//! Quickstart: the whole Puzzle pipeline — scenario → device-in-the-loop
//! GA analysis → Pareto front → live Coordinator — in one page, entirely
//! through the owned `puzzle::api` session layer.
//!
//! Run with: `cargo run --release --example quickstart`

use std::time::Duration;

use puzzle::analyzer::GaConfig;
use puzzle::api::{GenerationProgress, RuntimeOptions, ScenarioSpec, SessionBuilder};

fn main() {
    // 1. Describe the workload: one camera-synchronized model group with the
    //    MediaPipe face detector, selfie segmenter, and YOLOv8-nano analogs
    //    (zoo indices 0, 1, 6), on the paper-calibrated device model.
    let session = SessionBuilder::new(ScenarioSpec::single_group("quickstart", vec![0, 1, 6]))
        .config(GaConfig::quick(42))
        .build()
        .expect("valid scenario spec");
    let scenario = session.scenario();
    println!("scenario {}:", scenario.name);
    for net in &scenario.networks {
        println!(
            "  {:<12} {} layers, {} edges, {:.1}M MACs",
            net.name,
            net.num_layers(),
            net.num_edges(),
            net.total_macs() as f64 / 1e6
        );
    }

    // 2. Run the Static Analyzer, streaming per-generation progress.
    let analysis = session.run_observed(&mut |p: &GenerationProgress<'_>| {
        println!(
            "  gen {:>2}: {:>4} evals, avg {:.2}ms, plan memo {:>3.0}%, profile cache {:>3.0}%",
            p.generation,
            p.evaluations,
            p.avg_aggregate * 1e3,
            p.plan_cache_hit_rate() * 100.0,
            p.profile_cache_hit_rate() * 100.0,
        );
    });
    println!(
        "analysis: {} generations, {} evaluations, {} pareto solutions",
        analysis.generations_run,
        analysis.evaluations,
        analysis.pareto.len()
    );
    for (i, sol) in analysis.pareto.iter().enumerate() {
        let subgraphs: usize = sol.plans().iter().map(|p| p.tasks.len()).sum();
        println!(
            "  #{i}: objectives {:?} ({subgraphs} subgraphs)",
            sol.objectives.iter().map(|o| format!("{:.2}ms", o * 1e3)).collect::<Vec<_>>()
        );
    }

    // 3. Deploy the chosen solution: one call builds the runtime solutions
    //    and a ready Coordinator/Worker stack on the simulated engine.
    let best = analysis.best_index();
    println!("deploying pareto solution #{best}");
    let mut deployment = analysis
        .deploy(best, RuntimeOptions::default())
        .expect("deployable solution");

    // 4. Serve 10 synchronized group requests through the real runtime.
    let served = deployment.serve(0, 10, Duration::from_secs(10));
    let makespans = deployment.simulated_makespans();
    let (avg, sd) = puzzle::metrics::mean_sd(&makespans);
    println!(
        "served {served} group requests: simulated makespan {:.2} ± {:.2} ms",
        avg * 1e3,
        sd * 1e3
    );
    deployment.shutdown();

    // 5. Load-test the same solution under open-loop traffic: deploy on a
    //    non-sleeping engine and drive the deterministic virtual clock —
    //    periodic arrivals at the scenario's period, deadline accounting,
    //    all through the real Coordinator/Worker stack.
    use puzzle::api::LoadSpec;
    let mut lt = analysis
        .deploy_sim(best, RuntimeOptions::default(), 0.0, true, 42)
        .expect("deployable solution");
    let spec = LoadSpec::for_scenario(analysis.scenario(), analysis.perf(), 1.2, 30);
    let report = lt.serve_load(&spec);
    println!(
        "loadtest (alpha 1.2, virtual clock): {}/{} in deadline, p90 {:.2} ms, score {:.3}",
        report.served - report.violations,
        report.submitted,
        report.percentile(0, 0.9) * 1e3,
        report.score
    );
    lt.shutdown();
}
