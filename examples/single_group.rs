//! Single model group scenario (paper §6.3): run the Static Analyzer against
//! the NPU-Only and Best-Mapping baselines on one randomly generated
//! scenario, and report XRBench scores + saturation multipliers.
//!
//! Run with: `cargo run --release --example single_group [-- <scenario 1-10>]`

use puzzle::api::{ScenarioSpec, SessionBuilder};
use puzzle::baselines;
use puzzle::experiments::{saturation_of, score_at_alpha, solve_scenario_budgeted};
use puzzle::perf::PerfModel;

fn main() {
    let which: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1);
    let pm = PerfModel::paper_calibrated();
    // The api's generated-scenario spec replaces indexing into the raw
    // generator output.
    let session = SessionBuilder::new(ScenarioSpec::GeneratedSingle {
        seed: 23,
        index: (which - 1).min(9),
    })
    .perf_model(pm.clone())
    .build()
    .expect("valid generated-scenario index");
    let scenario = session.scenario().as_ref();
    println!("scenario {}: zoo models {:?}", scenario.name, scenario.zoo_indices);
    println!("base period: {:.2} ms", scenario.base_period(0, &pm) * 1e3);

    // Solve with all three methods.
    let (puzzle_sols, bm_sols, npu_sols) = solve_scenario_budgeted(scenario, &pm, 24, 20 + which as u64);
    println!(
        "puzzle pareto: {} solutions, best mapping pareto: {}, npu-only: 1",
        puzzle_sols.len(), bm_sols.len()
    );

    // Score each at a few period multipliers.
    println!("{:<8} {:>8} {:>14} {:>9}", "alpha", "puzzle", "best_mapping", "npu_only");
    for alpha in [0.6, 0.8, 1.0, 1.2, 1.6, 2.0] {
        let med = |sols: &Vec<Vec<puzzle::sim::ExecutionPlan>>| {
            let mut scores: Vec<f64> = sols
                .iter()
                .map(|p| score_at_alpha(p, scenario, alpha, &pm, 20))
                .collect();
            scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if scores.is_empty() { 0.0 } else { scores[scores.len() / 2] }
        };
        println!(
            "{:<8.1} {:>8.3} {:>14.3} {:>9.3}",
            alpha, med(&puzzle_sols), med(&bm_sols), med(&npu_sols)
        );
    }

    // Saturation multipliers (Fig 12's metric).
    let a_puzzle = saturation_of(&puzzle_sols, scenario, &pm, 20);
    let a_bm = saturation_of(&bm_sols, scenario, &pm, 20);
    let a_npu = saturation_of(&npu_sols, scenario, &pm, 20);
    println!("saturation multiplier α*:");
    println!("  puzzle       {:?} (paper mean 0.78)", a_puzzle);
    println!("  best mapping {:?} (paper mean 1.17)", a_bm);
    println!("  npu only     {:?} (paper mean 1.56)", a_npu);

    // Show what the baselines actually chose.
    let npu = baselines::npu_only(scenario, &pm, 20);
    println!(
        "npu-only avg makespan objective: {:.2} ms",
        npu.objectives[0] * 1e3
    );
}
