//! Multi model group scenario (paper §6.4): two model groups — one
//! lightweight (MediaPipe analogs), one heavy (YOLOv8 / Fast-SCNN /
//! TCMonoDepth analogs) — competing for the same processors; inspect the
//! Pareto trade-off between their makespans (the paper's Scenario 10).
//!
//! Run with: `cargo run --release --example multi_group`

use puzzle::analyzer::GaConfig;
use puzzle::api::SessionBuilder;
use puzzle::experiments::{saturation_of, score_at_alpha, solve_scenario_budgeted};
use puzzle::perf::PerfModel;
use puzzle::scenario::scenario10_analog;

fn main() {
    let pm = PerfModel::paper_calibrated();
    let scenario = scenario10_analog();
    println!("scenario {}:", scenario.name);
    for (g, group) in scenario.groups.iter().enumerate() {
        let names: Vec<&str> = group
            .members
            .iter()
            .map(|&m| scenario.networks[m].name.as_str())
            .collect();
        println!(
            "  group {}: {:?}, base period {:.2} ms",
            g, names, scenario.base_period(g, &pm) * 1e3
        );
    }

    // Run the Static Analyzer through the session layer and show the
    // makespan trade-off across the Pareto set (group 0 avg vs group 1 avg).
    let session = SessionBuilder::for_scenario(scenario.clone())
        .perf_model(pm.clone())
        .config(GaConfig::quick(210))
        .build()
        .expect("valid scenario");
    let analysis = session.run();
    println!(
        "analyzer: {} generations, {} evaluations, {} pareto solutions",
        analysis.generations_run, analysis.evaluations, analysis.pareto.len()
    );
    println!("{:>18} {:>18} {:>10}", "group0 avg (ms)", "group1 avg (ms)", "subgraphs");
    let mut rows: Vec<(f64, f64, usize)> = analysis
        .pareto
        .iter()
        .map(|s| {
            let sg: usize = s.plans().iter().map(|p| p.tasks.len()).sum();
            (s.objectives[0] * 1e3, s.objectives[2] * 1e3, sg)
        })
        .collect();
    rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (g0, g1, sg) in rows {
        println!("{:>18.2} {:>18.2} {:>10}", g0, g1, sg);
    }

    // Method comparison at lenient/tight periods (Fig 14/16 view).
    let (pz, bm, npu) = solve_scenario_budgeted(&scenario, &pm, 20, 210);
    println!("\nXRBench scores (median over solutions):");
    println!("{:<8} {:>8} {:>14} {:>9}", "alpha", "puzzle", "best_mapping", "npu_only");
    for alpha in [0.7, 0.9, 1.1, 1.4, 2.0, 3.0] {
        let med = |sols: &Vec<Vec<puzzle::sim::ExecutionPlan>>| {
            let mut s: Vec<f64> = sols
                .iter()
                .map(|p| score_at_alpha(p, &scenario, alpha, &pm, 20))
                .collect();
            s.sort_by(|a, b| a.partial_cmp(b).unwrap());
            if s.is_empty() { 0.0 } else { s[s.len() / 2] }
        };
        println!("{:<8.1} {:>8.3} {:>14.3} {:>9.3}", alpha, med(&pz), med(&bm), med(&npu));
    }
    println!("\nsaturation multipliers (paper means: 0.95 / 2.24 / 3.45):");
    println!("  puzzle       {:?}", saturation_of(&pz, &scenario, &pm, 20));
    println!("  best mapping {:?}", saturation_of(&bm, &scenario, &pm, 20));
    println!("  npu only     {:?}", saturation_of(&npu, &scenario, &pm, 20));
}
