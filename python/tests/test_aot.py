"""AOT path: artifact emission, HLO text structure, determinism."""

import json
import os

import jax
import pytest

from compile import aot, graphs, model

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def artifact_dir(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = {}
    # One light and one branchy model keep the fixture fast.
    for name in ("face_det", "selfie_seg"):
        aot.emit_model(graphs.by_name(name), str(out), manifest)
    with open(out / "manifest.json", "w") as f:
        json.dump(manifest, f)
    return out


class TestEmission:
    def test_whole_and_per_layer_files_exist(self, artifact_dir):
        g = graphs.by_name("face_det")
        assert (artifact_dir / "face_det.hlo.txt").exists()
        for li in range(len(g.layers)):
            assert (artifact_dir / f"face_det.layer{li:02d}.hlo.txt").exists()

    def test_hlo_text_parses_as_hlo_module(self, artifact_dir):
        text = (artifact_dir / "face_det.hlo.txt").read_text()
        assert text.startswith("HloModule"), text[:40]
        assert "ENTRY" in text
        # Whole model must mention convolution or dot (the compute).
        assert ("convolution" in text) or ("dot" in text)

    def test_entry_layout_matches_input_shape(self, artifact_dir):
        g = graphs.by_name("face_det")
        text = (artifact_dir / "face_det.hlo.txt").read_text()
        n, h, w, c = model.input_shape(g)
        assert f"f32[{n},{h},{w},{c}]" in text

    def test_manifest_records_layers(self, artifact_dir):
        manifest = json.loads((artifact_dir / "manifest.json").read_text())
        g = graphs.by_name("selfie_seg")
        entry = manifest["selfie_seg"]
        assert len(entry["layers"]) == len(g.layers)
        assert entry["input"] == list(model.input_shape(g))

    def test_lowering_is_deterministic(self):
        g = graphs.by_name("face_det")
        fn, shapes = model.layer_fn(g, 0)
        a = aot.lower_fn(fn, shapes)
        b = aot.lower_fn(fn, shapes)
        assert a == b, "HLO text must be reproducible"

    def test_join_layer_artifact_has_two_parameters(self, artifact_dir):
        # face_det layer 8 is the concat of the two heads.
        text = (artifact_dir / "face_det.layer08.hlo.txt").read_text()
        assert text.count("parameter(0)") >= 1
        assert text.count("parameter(1)") >= 1


class TestNonlinearitySubstrate:
    def test_whole_model_hlo_smaller_than_layer_sum(self, artifact_dir):
        """XLA fuses the whole-model lowering: its instruction count must be
        well below the sum of per-layer instruction counts — the *mechanism*
        behind the paper's Table 4 non-linearity."""
        whole = (artifact_dir / "face_det.hlo.txt").read_text()
        g = graphs.by_name("face_det")
        layer_total = 0
        for li in range(len(g.layers)):
            t = (artifact_dir / f"face_det.layer{li:02d}.hlo.txt").read_text()
            layer_total += t.count("=")
        # Parameter/boilerplate overhead per artifact guarantees slack.
        assert whole.count("=") < layer_total, (
            f"whole {whole.count('=')} vs layer-sum {layer_total}"
        )
