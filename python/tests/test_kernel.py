"""L1 correctness: the Pallas fused block vs the pure-jnp oracle.

This is the CORE correctness signal of the compile path: hypothesis sweeps
shapes/strides/dtypes and asserts allclose against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_block, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


class TestMatmulKernel:
    @settings(max_examples=40, deadline=None)
    @given(
        m=st.integers(1, 200),
        k=st.integers(1, 96),
        n=st.integers(1, 96),
        relu=st.booleans(),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_ref_across_shapes(self, m, k, n, relu, seed):
        x = rand((m, k), seed)
        w = rand((k, n), seed + 1)
        b = rand((n,), seed + 2)
        got = fused_block.matmul_bias_act(x, w, b, relu=relu)
        want = ref.matmul_bias_act_ref(x, w, b, relu=relu)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_relu_clamps_negatives(self):
        x = jnp.array([[-10.0, 10.0]], dtype=jnp.float32)
        w = jnp.eye(2, dtype=jnp.float32)
        b = jnp.zeros((2,), dtype=jnp.float32)
        got = fused_block.matmul_bias_act(x, w, b, relu=True)
        assert float(got[0, 0]) == 0.0
        assert float(got[0, 1]) == 10.0

    def test_no_relu_passes_negatives(self):
        x = jnp.array([[-3.0]], dtype=jnp.float32)
        w = jnp.ones((1, 1), dtype=jnp.float32)
        b = jnp.zeros((1,), dtype=jnp.float32)
        got = fused_block.matmul_bias_act(x, w, b, relu=False)
        assert float(got[0, 0]) == -3.0

    def test_bias_is_added(self):
        x = jnp.zeros((4, 3), dtype=jnp.float32)
        w = jnp.zeros((3, 5), dtype=jnp.float32)
        b = jnp.arange(5, dtype=jnp.float32)
        got = fused_block.matmul_bias_act(x, w, b, relu=False)
        np.testing.assert_allclose(got, jnp.broadcast_to(b, (4, 5)))

    @pytest.mark.parametrize("m,k,n", [(128, 64, 128), (129, 64, 127), (1, 1, 1), (256, 144, 160)])
    def test_tile_boundary_shapes(self, m, k, n):
        x = rand((m, k), 10)
        w = rand((k, n), 11)
        b = rand((n,), 12)
        got = fused_block.matmul_bias_act(x, w, b)
        want = ref.matmul_bias_act_ref(x, w, b)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_block_size_invariance(self):
        # Different tile choices must not change the numerics.
        x, w, b = rand((100, 48), 1), rand((48, 72), 2), rand((72,), 3)
        a = fused_block.matmul_bias_act(x, w, b, block_m=32, block_n=32)
        c = fused_block.matmul_bias_act(x, w, b, block_m=128, block_n=128)
        # Different tilings reorder the f32 accumulation; allow ulp-scale drift.
        np.testing.assert_allclose(a, c, rtol=1e-3, atol=1e-5)


class TestConvKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        size=st.sampled_from([4, 8, 16, 32]),
        cin=st.integers(1, 16),
        cout=st.integers(1, 16),
        stride=st.sampled_from([1, 2]),
        k=st.sampled_from([1, 3]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_matches_lax_conv(self, size, cin, cout, stride, k, seed):
        x = rand((1, size, size, cin), seed)
        w = rand((k, k, cin, cout), seed + 1)
        b = rand((cout,), seed + 2)
        got = fused_block.conv2d_bias_act(x, w, b, stride=stride)
        want = ref.conv2d_bias_act_ref(x, w, b, stride=stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_identity_kernel(self):
        # 1x1 conv with identity weights reproduces (relu of) the input.
        x = rand((1, 8, 8, 4), 5)
        w = jnp.eye(4, dtype=jnp.float32).reshape(1, 1, 4, 4)
        b = jnp.zeros((4,), dtype=jnp.float32)
        got = fused_block.conv2d_bias_act(x, w, b, relu=True)
        np.testing.assert_allclose(got, jnp.maximum(x, 0.0), rtol=1e-6)

    def test_stride_halves_spatial(self):
        x = rand((1, 16, 16, 3), 6)
        w = rand((3, 3, 3, 7), 7)
        b = rand((7,), 8)
        got = fused_block.conv2d_bias_act(x, w, b, stride=2)
        assert got.shape == (1, 8, 8, 7)


class TestAuxOps:
    def test_dwconv_ref_shapes_and_channels_independent(self):
        # Depthwise conv must not mix channels: zeroing one channel's filter
        # zeroes exactly that output channel (bias 0).
        x = rand((1, 8, 8, 3), 9)
        w = np.random.default_rng(1).normal(size=(3, 3, 3)).astype(np.float32)
        w[:, :, 1] = 0.0
        b = jnp.zeros((3,), dtype=jnp.float32)
        out = ref.dwconv2d_bias_act_ref(x, jnp.asarray(w), b)
        assert float(jnp.abs(out[..., 1]).max()) == 0.0
        assert float(jnp.abs(out[..., 0]).max()) > 0.0

    def test_upsample_repeats(self):
        x = jnp.arange(4, dtype=jnp.float32).reshape(1, 2, 2, 1)
        up = ref.upsample2x_ref(x)
        assert up.shape == (1, 4, 4, 1)
        np.testing.assert_allclose(up[0, :2, :2, 0], jnp.full((2, 2), x[0, 0, 0, 0]))

    def test_avgpool_means(self):
        x = jnp.arange(16, dtype=jnp.float32).reshape(1, 4, 4, 1)
        p = ref.avgpool2x_ref(x)
        assert p.shape == (1, 2, 2, 1)
        np.testing.assert_allclose(p[0, 0, 0, 0], (0 + 1 + 4 + 5) / 4.0)

    def test_pool_upsample_roundtrip_on_constant(self):
        x = jnp.full((1, 8, 8, 2), 3.5, dtype=jnp.float32)
        np.testing.assert_allclose(ref.upsample2x_ref(ref.avgpool2x_ref(x)), x)


class TestVmemEstimates:
    def test_footprint_scales_with_blocks(self):
        small = fused_block.vmem_footprint_bytes(64, 64, 64)
        big = fused_block.vmem_footprint_bytes(1024, 1024, 1024)
        assert big > small

    def test_footprint_within_vmem_budget_for_zoo_shapes(self):
        # Largest zoo matmul: 256x(9*160) @ (9*160)x160 (mosaic/fastsam
        # 8x8 layers are small; the 16x16x160 convs dominate).
        fp = fused_block.vmem_footprint_bytes(256, 9 * 160, 160)
        assert fp < 16 * 1024 * 1024, f"VMEM estimate {fp} exceeds 16 MiB"

    def test_utilization_bounds(self):
        for (m, k, n) in [(1, 1, 1), (128, 128, 128), (100, 37, 60), (1024, 512, 256)]:
            u = fused_block.mxu_utilization_estimate(m, k, n)
            assert 0.0 < u <= 1.0, (m, k, n, u)
        assert fused_block.mxu_utilization_estimate(128, 128, 128) == 1.0
