"""L2 correctness: the jax model zoo graphs.

Checks (a) whole-model vs layer-chain composition equality — the property
the rust runtime relies on when executing partitioned subgraphs — and
(b) structural agreement with the declared graph specs.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import graphs, model

jax.config.update("jax_platform_name", "cpu")

ZOO = graphs.model_zoo()
NAMES = [g.name for g in ZOO]


def rand_input(g, seed=0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=model.input_shape(g)).astype(np.float32)
    )


class TestGraphStructure:
    def test_zoo_has_nine_models(self):
        assert len(ZOO) == 9
        assert NAMES[0] == "face_det" and NAMES[-1] == "fastsam"

    @pytest.mark.parametrize("g", ZOO, ids=NAMES)
    def test_single_input_dag(self, g):
        assert len(g.inputs()) == 1
        order = g.topo_order()
        assert len(order) == len(g.layers)

    @pytest.mark.parametrize("g", ZOO, ids=NAMES)
    def test_channel_consistency(self, g):
        for li, spec in enumerate(g.layers):
            preds = g.predecessors(li)
            if not preds:
                continue
            if spec.kind == "concat":
                total = sum(g.layers[p].out_c for p in preds)
                assert spec.in_c == total, f"{g.name}:{spec.name}"
            elif spec.kind == "add":
                for p in preds:
                    assert g.layers[p].out_shape == spec.out_shape, f"{g.name}:{spec.name}"
            else:
                assert len(preds) == 1
                assert spec.in_c == g.layers[preds[0]].out_c, f"{g.name}:{spec.name}"

    @pytest.mark.parametrize("g", ZOO, ids=NAMES)
    def test_every_model_has_a_join(self, g):
        assert any(len(g.predecessors(i)) > 1 for i in range(len(g.layers))), g.name


class TestModelExecution:
    @pytest.mark.parametrize("g", ZOO, ids=NAMES)
    def test_whole_model_runs_and_shapes_match(self, g):
        outs = model.run_whole(g, rand_input(g))
        assert len(outs) == len(g.outputs())
        for o, li in zip(outs, g.outputs()):
            assert o.shape == (1, *g.layers[li].out_shape), f"{g.name}:{g.layers[li].name}"
            assert bool(jnp.isfinite(o).all()), g.name

    @pytest.mark.parametrize("g", ZOO, ids=NAMES)
    def test_layer_chain_equals_whole(self, g):
        """The composition property the rust PjrtEngine depends on."""
        x = rand_input(g, seed=1)
        whole = model.run_whole(g, x)
        chain = model.run_layer_chain(g, x)
        for a, b in zip(whole, chain):
            np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    def test_pallas_path_equals_jnp_path(self):
        """use_pallas toggles the L1 kernel; numerics must agree."""
        for g in ZOO[:3]:
            x = rand_input(g, seed=2)
            with_pallas = model.run_whole(g, x, use_pallas=True)
            without = model.run_whole(g, x, use_pallas=False)
            for a, b in zip(with_pallas, without):
                np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    def test_weights_are_deterministic(self):
        g = ZOO[0]
        w1 = model.layer_weights(g.name, g.layers[0])
        w2 = model.layer_weights(g.name, g.layers[0])
        np.testing.assert_array_equal(w1["w"], w2["w"])
        # Different layer -> different weights.
        w3 = model.layer_weights(g.name, g.layers[5])
        assert w1["w"].shape != w3["w"].shape or not np.array_equal(w1["w"], w3["w"])

    def test_outputs_differ_across_inputs(self):
        g = ZOO[0]
        o1 = model.run_whole(g, rand_input(g, seed=3))
        o2 = model.run_whole(g, rand_input(g, seed=4))
        assert float(jnp.abs(o1[0] - o2[0]).max()) > 0.0
