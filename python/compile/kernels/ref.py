"""Pure-jnp correctness oracles for the Pallas kernels.

These are the ground truth the pytest/hypothesis suites compare against:
no Pallas, no custom tiling — just the obvious jnp expression of each op.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_bias_act_ref(x, w, b, relu: bool = True):
    """act(x @ w + b) — the oracle for fused_block.matmul_bias_act."""
    out = jnp.dot(x, w, preferred_element_type=jnp.float32) + b[None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def conv2d_bias_act_ref(x, w, b, stride: int = 1, relu: bool = True):
    """Same-padded KxK conv + bias (+ ReLU) via lax.conv — the oracle for
    fused_block.conv2d_bias_act. x: [1,H,W,Cin], w: [K,K,Cin,Cout]."""
    out = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    out = out + b[None, None, None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def dwconv2d_bias_act_ref(x, w, b, stride: int = 1, relu: bool = True):
    """Depthwise same-padded conv + bias + ReLU.
    x: [1,H,W,C], w: [K,K,C] per-channel filters, b: [C]."""
    c = x.shape[-1]
    # HWIO with feature_group_count=C: w shaped [K,K,1,C].
    wf = w[:, :, None, :]
    out = jax.lax.conv_general_dilated(
        x,
        wf,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )
    out = out + b[None, None, None, :]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def upsample2x_ref(x):
    """Nearest-neighbour 2x upsample, NHWC."""
    return jnp.repeat(jnp.repeat(x, 2, axis=1), 2, axis=2)


def avgpool2x_ref(x):
    """2x2 average pool, stride 2, NHWC."""
    n, h, w, c = x.shape
    return x.reshape(n, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))
