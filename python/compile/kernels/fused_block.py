"""L1 — the Pallas compute hot-spot: a fused, tiled matmul + bias +
activation block.

Every compute-heavy layer of the model zoo (conv via im2col, pointwise,
dense head) lowers onto this kernel, so it is the system's MXU workload.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's kernels
target a mobile GPU/NPU; here the same computation is structured for a
TPU-like machine instead of being mechanically ported:

* the conv is expressed as a *blocked matmul* — the MXU's native shape —
  rather than a thread-per-pixel GPU kernel;
* `BlockSpec`s express the HBM↔VMEM schedule (x-tile and w-tile streamed
  per grid step, full-K accumulation in VMEM) that a CUDA kernel would
  express with threadblock tiling and shared-memory staging;
* block sizes are chosen so x-block + w-block + acc fit a conservative
  VMEM budget (see `vmem_footprint_bytes`).

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls, so the kernel lowers to plain HLO; numerics are validated
against `ref.py`, and TPU efficiency is *estimated* from the block schedule
(EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-friendly tile sizes. The MXU is a 128x128 systolic array;
# 128-multiples keep it saturated when shapes allow, while tiny zoo shapes
# fall back to single-tile grids via padding.
BLOCK_M = 128
BLOCK_N = 128


def _matmul_bias_act_kernel(x_ref, w_ref, b_ref, o_ref, *, relu: bool):
    """One (BLOCK_M, BLOCK_N) output tile: full-K matmul + bias (+ ReLU).

    K is not tiled: a (BLOCK_M, K) x-slab and (K, BLOCK_N) w-slab are staged
    in VMEM per grid step and contracted in one MXU pass (preferred on TPU
    when K fits — avoids accumulator revisits).
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    acc = acc + b_ref[...][None, :]
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def _pad_to(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("relu", "block_m", "block_n"))
def matmul_bias_act(
    x: jax.Array,
    w: jax.Array,
    b: jax.Array,
    relu: bool = True,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
) -> jax.Array:
    """Fused `act(x @ w + b)` via the Pallas kernel.

    x: [M, K] f32, w: [K, N] f32, b: [N] f32 -> [M, N] f32.
    Shapes are padded up to tile multiples and the result sliced back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    assert b.shape == (n,)

    bm = min(block_m, -(-m // 8) * 8)  # shrink tiles for tiny inputs
    bn = min(block_n, -(-n // 8) * 8)
    xp = _pad_to(x, 0, bm)
    wp = _pad_to(w, 1, bn)
    bp = _pad_to(b, 0, bn)
    mp, np_ = xp.shape[0], wp.shape[1]

    grid = (mp // bm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_matmul_bias_act_kernel, relu=relu),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(xp, wp, bp)
    return out[:m, :n]


def conv2d_bias_act(
    x: jax.Array, w: jax.Array, b: jax.Array, stride: int = 1, relu: bool = True
) -> jax.Array:
    """KxK same-padded conv as im2col + the fused Pallas matmul.

    x: [1, H, W, Cin], w: [K, K, Cin, Cout], b: [Cout] -> [1, H/s, W/s, Cout].
    """
    kh, kw, cin, cout = w.shape
    patches = jax.lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )  # [1, H/s, W/s, K*K*Cin] with feature order (Cin, kh, kw)
    _, ho, wo, feat = patches.shape
    cols = patches.reshape(ho * wo, feat)
    # conv_general_dilated_patches emits features as (Cin, kh, kw);
    # reorder the weight tensor to match.
    wmat = jnp.transpose(w, (2, 0, 1, 3)).reshape(feat, cout)
    out = matmul_bias_act(cols, wmat, b, relu=relu)
    return out.reshape(1, ho, wo, cout)


def dense_bias(x_flat: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Dense head (no activation) on [1, F] features via the same kernel."""
    return matmul_bias_act(x_flat, w, b, relu=False)


def vmem_footprint_bytes(m: int, k: int, n: int, block_m: int = BLOCK_M, block_n: int = BLOCK_N) -> int:
    """Estimated per-step VMEM residency of the kernel (f32): the x-slab,
    w-slab, bias tile, and output accumulator. Used by the perf notes in
    EXPERIMENTS.md §Perf (interpret mode gives no real TPU numbers)."""
    bm = min(block_m, m)
    bn = min(block_n, n)
    return 4 * (bm * k + k * bn + bn + bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int, block_m: int = BLOCK_M, block_n: int = BLOCK_N) -> float:
    """Fraction of MXU lanes kept busy by the tile shapes: the product of
    each dimension's occupancy of its 128-lane tile, amortized over the
    padded grid. 1.0 = perfectly aligned shapes."""
    bm = min(block_m, max(8, m))
    bn = min(block_n, max(8, n))
    grid_m = -(-m // bm)
    grid_n = -(-n // bn)
    useful = m * k * n
    padded = (grid_m * bm) * k * (grid_n * bn)
    lane_m = min(m, 128) / 128.0 if m < 128 else 1.0
    lane_n = min(n, 128) / 128.0 if n < 128 else 1.0
    return (useful / padded) * lane_m * lane_n
