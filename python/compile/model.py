"""L2 — the jax compute graphs of the model zoo.

Builds, for each `graphs.GraphSpec`:

* a **whole-model** jax function `input -> (outputs...)`;
* **per-layer** jax functions `(pred tensors...) -> (out,)` — the units the
  rust runtime chains when a Static-Analyzer solution partitions a model.

Compute-heavy layers (conv / pointwise / dense) lower onto the L1 Pallas
fused block ([`kernels.fused_block`]); cheap memory-bound ops (depthwise
conv, joins, resampling) stay in plain jnp/lax. Weights are deterministic
per (model, layer) — baked into the lowered HLO as constants, so artifacts
are self-contained and reproducible.
"""

from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .graphs import GraphSpec, LayerSpec, by_name, model_zoo  # noqa: F401
from .kernels import fused_block, ref


def _weight_rng(model: str, layer: str) -> np.random.Generator:
    """Deterministic per-(model, layer) generator (stable artifact bytes)."""
    seed = abs(hash((model, layer))) % (2**32)
    # hash() is salted per-process; use a stable FNV instead.
    h = 2166136261
    for ch in f"{model}/{layer}".encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    del seed
    return np.random.default_rng(h)


def layer_weights(model: str, spec: LayerSpec) -> Dict[str, np.ndarray]:
    """Materialize the weights of one layer (empty dict for weightless ops)."""
    rng = _weight_rng(model, spec.name)
    scale = lambda fan_in: 1.0 / np.sqrt(max(fan_in, 1))
    if spec.kind == "conv":
        fan = spec.k * spec.k * spec.in_c
        return {
            "w": rng.normal(0, scale(fan), (spec.k, spec.k, spec.in_c, spec.out_c)).astype(np.float32),
            "b": rng.normal(0, 0.01, (spec.out_c,)).astype(np.float32),
        }
    if spec.kind == "dwconv":
        return {
            "w": rng.normal(0, scale(spec.k * spec.k), (spec.k, spec.k, spec.out_c)).astype(np.float32),
            "b": rng.normal(0, 0.01, (spec.out_c,)).astype(np.float32),
        }
    if spec.kind == "pointwise":
        return {
            "w": rng.normal(0, scale(spec.in_c), (1, 1, spec.in_c, spec.out_c)).astype(np.float32),
            "b": rng.normal(0, 0.01, (spec.out_c,)).astype(np.float32),
        }
    if spec.kind == "dense":
        return {
            "w": rng.normal(0, scale(spec.in_c), (spec.in_c, spec.out_c)).astype(np.float32),
            "b": rng.normal(0, 0.01, (spec.out_c,)).astype(np.float32),
        }
    return {}


def apply_layer(model: str, spec: LayerSpec, inputs: List[jax.Array], use_pallas: bool = True) -> jax.Array:
    """Execute one layer on its input tensors (NHWC, N=1)."""
    w = layer_weights(model, spec)
    if spec.kind == "conv":
        fn = fused_block.conv2d_bias_act if use_pallas else ref.conv2d_bias_act_ref
        return fn(inputs[0], jnp.asarray(w["w"]), jnp.asarray(w["b"]), stride=spec.s)
    if spec.kind == "pointwise":
        fn = fused_block.conv2d_bias_act if use_pallas else ref.conv2d_bias_act_ref
        return fn(inputs[0], jnp.asarray(w["w"]), jnp.asarray(w["b"]), stride=1)
    if spec.kind == "dwconv":
        return ref.dwconv2d_bias_act_ref(
            inputs[0], jnp.asarray(w["w"]), jnp.asarray(w["b"]), stride=spec.s
        )
    if spec.kind == "add":
        out = inputs[0]
        for x in inputs[1:]:
            out = out + x
        return out
    if spec.kind == "concat":
        return jnp.concatenate(inputs, axis=-1)
    if spec.kind == "upsample":
        return ref.upsample2x_ref(inputs[0])
    if spec.kind == "pool":
        return ref.avgpool2x_ref(inputs[0])
    if spec.kind == "dense":
        feats = inputs[0].mean(axis=(1, 2))  # global average pool -> [1, C]
        return fused_block.dense_bias(feats, jnp.asarray(w["w"]), jnp.asarray(w["b"]))
    raise ValueError(f"unknown layer kind {spec.kind}")


def input_shape(g: GraphSpec) -> Tuple[int, int, int, int]:
    """Network input NHWC shape (all zoo models: one image input)."""
    (first,) = g.inputs() if len(g.inputs()) == 1 else (g.inputs()[0],)
    spec = g.layers[first]
    return (1, spec.size, spec.size, spec.in_c)


def whole_model_fn(g: GraphSpec, use_pallas: bool = True) -> Callable:
    """The whole network as one jax function `input -> tuple(outputs)`."""

    def fn(x: jax.Array):
        produced: Dict[int, jax.Array] = {}
        for li in g.topo_order():
            preds = g.predecessors(li)
            ins = [x] if not preds else [produced[p] for p in preds]
            produced[li] = apply_layer(g.name, g.layers[li], ins, use_pallas)
        return tuple(produced[o] for o in g.outputs())

    return fn


def layer_fn(g: GraphSpec, layer: int, use_pallas: bool = True) -> Tuple[Callable, List[Tuple[int, ...]]]:
    """One layer as a jax function plus its input shapes (one per
    predecessor, or the network input shape for root layers)."""
    preds = g.predecessors(layer)
    if preds:
        shapes = [(1, *g.layers[p].out_shape) for p in preds]
    else:
        shapes = [input_shape(g)]

    def fn(*ins):
        return (apply_layer(g.name, g.layers[layer], list(ins), use_pallas),)

    return fn, shapes


def run_whole(g: GraphSpec, x: jax.Array, use_pallas: bool = True):
    """Eager helper for tests."""
    return whole_model_fn(g, use_pallas)(x)


def run_layer_chain(g: GraphSpec, x: jax.Array, use_pallas: bool = True):
    """Execute the model layer-by-layer through `layer_fn`s (the composition
    the rust PjrtEngine performs); must equal `run_whole`."""
    produced: Dict[int, jax.Array] = {}
    for li in g.topo_order():
        preds = g.predecessors(li)
        ins = [x] if not preds else [produced[p] for p in preds]
        fn, _ = layer_fn(g, li, use_pallas)
        produced[li] = fn(*ins)[0]
    return tuple(produced[o] for o in g.outputs())
