"""Model-zoo graph definitions — the python mirror of rust/src/models/zoo.rs.

Layer indices and edge insertion order MUST match the rust side exactly:
the rust runtime addresses per-layer artifacts as `{model}.layer{NN}.hlo.txt`
where NN is the rust LayerId, and concat joins consume predecessors in edge
insertion order.

Layer spec fields:
    kind   : conv | dwconv | pointwise | add | concat | upsample | pool | dense
    size   : input spatial extent (square, NHWC with N=1)
    in_c   : input channels (sum over inputs for concat)
    out_c  : output channels
    k, s   : kernel size / stride (conv kinds only)
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Tuple


@dataclass(frozen=True)
class LayerSpec:
    name: str
    kind: str
    size: int
    in_c: int
    out_c: int
    k: int = 3
    s: int = 1

    @property
    def out_size(self) -> int:
        if self.kind in ("conv", "dwconv"):
            return self.size // self.s
        if self.kind == "pool":
            return self.size // 2
        if self.kind == "upsample":
            return self.size * 2
        if self.kind == "dense":
            return 1
        return self.size

    @property
    def out_shape(self) -> Tuple[int, int, int]:
        return (self.out_size, self.out_size, self.out_c)


@dataclass
class GraphSpec:
    name: str
    layers: List[LayerSpec]
    edges: List[Tuple[int, int]] = field(default_factory=list)

    def predecessors(self, layer: int) -> List[int]:
        """Predecessors in edge-insertion order (concat operand order)."""
        return [src for (src, dst) in self.edges if dst == layer]

    def successors(self, layer: int) -> List[int]:
        return [dst for (src, dst) in self.edges if src == layer]

    def inputs(self) -> List[int]:
        return [i for i in range(len(self.layers)) if not self.predecessors(i)]

    def outputs(self) -> List[int]:
        return [i for i in range(len(self.layers)) if not self.successors(i)]

    def topo_order(self) -> List[int]:
        indeg = {i: len(self.predecessors(i)) for i in range(len(self.layers))}
        ready = sorted(i for i, d in indeg.items() if d == 0)
        order: List[int] = []
        while ready:
            ready.sort()
            cur = ready.pop(0)
            order.append(cur)
            for nxt in self.successors(cur):
                indeg[nxt] -= 1
                if indeg[nxt] == 0:
                    ready.append(nxt)
        assert len(order) == len(self.layers), f"cycle in {self.name}"
        return order


def _conv(name, size, in_c, out_c, k=3, s=1):
    return LayerSpec(name, "conv", size, in_c, out_c, k, s)


def _dw(name, size, c, k=3, s=1):
    return LayerSpec(name, "dwconv", size, c, c, k, s)


def _pw(name, size, in_c, out_c):
    return LayerSpec(name, "pointwise", size, in_c, out_c, 1, 1)


def _add(name, size, c):
    return LayerSpec(name, "add", size, c, c)


def _cat(name, size, total_c):
    return LayerSpec(name, "concat", size, total_c, total_c)


def _up(name, size, c):
    return LayerSpec(name, "upsample", size, c, c)


def _pool(name, size, c):
    return LayerSpec(name, "pool", size, c, c)


def face_det() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 8, s=2),
        _dw("b1_dw", 16, 8),
        _pw("b1_pw", 16, 8, 12),
        _dw("b2_dw", 16, 12, s=2),
        _pw("b2_pw", 8, 12, 16),
        _conv("trunk", 8, 16, 16),
        _conv("head_box", 8, 16, 8),
        _conv("head_cls", 8, 16, 4),
        _cat("out", 8, 12),
    ]
    edges = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (5, 7), (6, 8), (7, 8)]
    return GraphSpec("face_det", layers, edges)


def selfie_seg() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 8),
        _conv("enc1", 32, 8, 12, s=2),
        _conv("enc2", 16, 12, 16, s=2),
        _conv("mid", 8, 16, 16),
        _up("up1", 8, 16),
        _pw("dec1", 16, 16, 12),
        _add("skip", 16, 12),
        _up("up2", 16, 12),
        _pw("mask", 32, 12, 2),
    ]
    edges = [(0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (1, 6), (6, 7), (7, 8)]
    return GraphSpec("selfie_seg", layers, edges)


def hand_det() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 16),
        _conv("c1", 32, 16, 24, s=2),
        _conv("c2", 16, 24, 24),
        _add("res", 16, 24),
        _conv("c3", 16, 24, 32, s=2),
        _conv("c4", 8, 32, 32),
        _conv("trunk", 8, 32, 32),
        _conv("head_palm", 8, 32, 16),
        _conv("head_lm", 8, 32, 16),
        _cat("out", 8, 32),
    ]
    edges = [(0, 1), (1, 2), (2, 3), (1, 3), (3, 4), (4, 5), (5, 6), (6, 7), (6, 8), (7, 9), (8, 9)]
    return GraphSpec("hand_det", layers, edges)


def pose_det() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 16),
        _conv("c1", 32, 16, 24, s=2),
        _conv("c2", 16, 24, 32),
        _conv("c3", 16, 32, 32),
        _add("res", 16, 32),
        _conv("c4", 16, 32, 40, s=2),
        _conv("c5", 8, 40, 40),
        _conv("trunk", 8, 40, 40),
        _conv("head_box", 8, 40, 16),
        _conv("head_kp", 8, 40, 16),
        _cat("out", 8, 32),
    ]
    edges = [
        (0, 1), (1, 2), (2, 3), (3, 4), (2, 4), (4, 5), (5, 6), (6, 7),
        (7, 8), (7, 9), (8, 10), (9, 10),
    ]
    return GraphSpec("pose_det", layers, edges)


def tcmonodepth() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 32),
        _conv("enc1", 32, 32, 32, s=2),
        _conv("enc2", 16, 32, 48),
        _conv("enc3", 16, 48, 64, s=2),
        _conv("mid1", 8, 64, 64),
        _conv("mid2", 8, 64, 64),
        _up("up1", 8, 64),
        _conv("dec1", 16, 64, 32),
        _add("skip1", 16, 32),
        _up("up2", 16, 32),
        _conv("dec2", 32, 32, 12),
        _pw("depth", 32, 12, 1),
    ]
    edges = [
        (0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (7, 8),
        (1, 8), (8, 9), (9, 10), (10, 11),
    ]
    return GraphSpec("tcmonodepth", layers, edges)


def fast_scnn() -> GraphSpec:
    layers = [
        _conv("lds1", 32, 3, 32, s=2),
        _dw("lds2_dw", 16, 32),
        _pw("lds2_pw", 16, 32, 48),
        _conv("gfe1", 16, 48, 96, s=2),
        _conv("gfe2", 8, 96, 96),
        _conv("gfe3", 8, 96, 96),
        _up("gfe_up", 8, 96),
        _pw("gfe_proj", 16, 96, 48),
        _add("fuse", 16, 48),
        _conv("fusion_conv", 16, 48, 64),
        _up("up", 16, 64),
        _pw("classifier", 32, 64, 4),
    ]
    edges = [
        (0, 1), (1, 2), (2, 3), (3, 4), (4, 5), (5, 6), (6, 7), (7, 8),
        (2, 8), (8, 9), (9, 10), (10, 11),
    ]
    return GraphSpec("fast_scnn", layers, edges)


def yolov8n() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 32),
        _conv("c1", 32, 32, 64, s=2),
        _pw("csp_a", 16, 64, 32),
        _pw("csp_b", 16, 64, 32),
        _conv("bneck1", 16, 32, 32),
        _conv("bneck2", 16, 32, 32),
        _cat("csp_join", 16, 64),
        _conv("c2", 16, 64, 96, s=2),
        _conv("c3", 8, 96, 96),
        _conv("neck", 8, 96, 96),
        _conv("head_p3", 16, 64, 16),
        _conv("head_p4", 8, 96, 32),
        _conv("head_p5", 8, 96, 32),
        _cat("out_p45", 8, 64),
    ]
    edges = [
        (0, 1), (1, 2), (1, 3), (2, 4), (4, 5), (5, 6), (3, 6), (6, 7),
        (7, 8), (8, 9), (6, 10), (9, 11), (9, 12), (11, 13), (12, 13),
    ]
    return GraphSpec("yolov8n", layers, edges)


def mosaic() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 48),
        _conv("enc1", 32, 48, 96, s=2),
        _conv("enc2", 16, 96, 96),
        _conv("enc3", 16, 96, 96),
        _add("res1", 16, 96),
        _conv("enc4", 16, 96, 128, s=2),
        _conv("enc5", 8, 128, 128),
        _conv("enc6", 8, 128, 128),
        _add("res2", 8, 128),
        _up("up1", 8, 128),
        _pw("proj1", 16, 128, 96),
        _add("agg", 16, 96),
        _conv("dec1", 16, 96, 64),
        _up("up2", 16, 64),
        _conv("dec2", 32, 64, 32),
        _pw("seg", 32, 32, 8),
    ]
    edges = [
        (0, 1), (1, 2), (2, 3), (3, 4), (2, 4), (4, 5), (5, 6), (6, 7),
        (7, 8), (6, 8), (8, 9), (9, 10), (10, 11), (4, 11), (11, 12),
        (12, 13), (13, 14), (14, 15),
    ]
    return GraphSpec("mosaic", layers, edges)


def fastsam() -> GraphSpec:
    layers = [
        _conv("stem", 32, 3, 48),
        _conv("c1", 32, 48, 96, s=2),
        _pw("csp_a", 16, 96, 64),
        _pw("csp_b", 16, 96, 64),
        _conv("bneck1", 16, 64, 64),
        _conv("bneck2", 16, 64, 64),
        _conv("bneck3", 16, 64, 64),
        _cat("csp_join", 16, 128),
        _conv("c2", 16, 128, 160, s=2),
        _conv("c3", 8, 160, 160),
        _conv("neck", 8, 160, 160),
        _conv("head_det", 8, 160, 64),
        _up("mask_up", 8, 160),
        _conv("mask1", 16, 160, 64),
        _conv("mask2", 16, 64, 32),
        _cat("out", 8, 96),
        _pool("mask_pool", 16, 32),
    ]
    edges = [
        (0, 1), (1, 2), (1, 3), (2, 4), (4, 5), (5, 6), (6, 7), (3, 7),
        (7, 8), (8, 9), (9, 10), (10, 11), (10, 12), (12, 13), (13, 14),
        (14, 16), (11, 15), (16, 15),
    ]
    return GraphSpec("fastsam", layers, edges)


#: Table 6 order — must match rust models::SPECS.
ZOO = [
    face_det, selfie_seg, hand_det, pose_det, tcmonodepth,
    fast_scnn, yolov8n, mosaic, fastsam,
]


def model_zoo() -> List[GraphSpec]:
    return [f() for f in ZOO]


def by_name(name: str) -> GraphSpec:
    for f in ZOO:
        g = f()
        if g.name == name:
            return g
    raise KeyError(name)
