"""AOT lowering: jax → HLO **text** artifacts for the rust PJRT runtime.

Emits, per zoo model:
  * ``{name}.hlo.txt``           — whole-model lowering (fused; the Table 4
                                   "measured" path and the quickstart demo);
  * ``{name}.layer{NN}.hlo.txt`` — one artifact per layer (the units the
                                   rust engine chains per subgraph);
plus ``manifest.json`` describing every artifact's I/O shapes.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits HloModuleProtos with 64-bit instruction ids which xla_extension 0.5.1
(the version the published ``xla`` crate binds) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Python runs ONLY here (``make artifacts``); never on the request path.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from .graphs import model_zoo
from .model import input_shape, layer_fn, whole_model_fn


def to_hlo_text(lowered) -> str:
    """Convert a jax-lowered computation to XLA HLO text."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_fn(fn, arg_shapes) -> str:
    specs = [jax.ShapeDtypeStruct(s, jax.numpy.float32) for s in arg_shapes]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def emit_model(g, out_dir: str, manifest: dict) -> None:
    in_shape = input_shape(g)

    # Whole model.
    whole = lower_fn(whole_model_fn(g), [in_shape])
    whole_path = os.path.join(out_dir, f"{g.name}.hlo.txt")
    with open(whole_path, "w") as f:
        f.write(whole)
    manifest[g.name] = {
        "input": list(in_shape),
        "outputs": [[1, *g.layers[o].out_shape] for o in g.outputs()],
        "layers": {},
    }

    # Per-layer artifacts.
    for li in range(len(g.layers)):
        fn, shapes = layer_fn(g, li)
        hlo = lower_fn(fn, shapes)
        path = os.path.join(out_dir, f"{g.name}.layer{li:02d}.hlo.txt")
        with open(path, "w") as f:
            f.write(hlo)
        manifest[g.name]["layers"][li] = {
            "name": g.layers[li].name,
            "kind": g.layers[li].kind,
            "inputs": [list(s) for s in shapes],
            "output": [1, *g.layers[li].out_shape],
        }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifact output directory")
    ap.add_argument("--models", default="", help="comma-separated subset of model names")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    wanted = {m for m in args.models.split(",") if m}
    manifest: dict = {}
    for g in model_zoo():
        if wanted and g.name not in wanted:
            continue
        print(f"lowering {g.name} ({len(g.layers)} layers)...", flush=True)
        emit_model(g, args.out, manifest)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    n_files = len(os.listdir(args.out))
    print(f"wrote {n_files} artifacts to {args.out}")


if __name__ == "__main__":
    main()
